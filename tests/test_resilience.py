"""repro.resilience: exact-resume checkpointing, elastic worker pools, and
the chaos-injection harness.

Three layers under test:

- state round-trips — replay tables (selector internals verbatim, so the
  restored table draws the SAME sample sequence), the run-wide
  ``RunCheckpointer`` manifest protocol, and the kill-and-restart parity
  pin (a SIGKILLed single-process run, resumed, is bit-identical to an
  uninterrupted one);
- elastic supervision — the multiprocess launcher classifies worker deaths
  (crash / preemption / shutdown), respawns within the restart budget, and
  fails fast once it is exhausted;
- chaos — seeded kill schedules and courier RPC fault injection, ending in
  the acceptance test that kills an actor mid-training and still learns.

Worker/service classes are module-level so the multiprocess backend can
pickle them into spawn children.
"""
import dataclasses
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.replay import (MinSize, Prioritized, Table, Uniform,
                          make_replay_shards)
from repro.resilience import (CRASH, PREEMPTED, SHUTDOWN, ChaosPolicy,
                              KillSchedule, RestartPolicy, RPCChaosInjector,
                              RunCheckpointer, classify_exit)

JOIN_S = 60


# ===================================================== replay round-trips
def test_prioritized_table_roundtrip_draws_identically():
    """The restored table must continue the EXACT sample stream of the
    original — sum-tree array and RNG restored verbatim, even into a table
    constructed with a different selector seed."""
    src = Table("p", 64, Prioritized(priority_exponent=0.6, seed=1),
                MinSize(1))
    rng = np.random.RandomState(0)
    for i in range(20):
        src.insert(i, priority=float(rng.rand()) + 0.1)
    for _ in range(5):
        src.sample(2)
    state = src.state_dict()

    dst = Table("p", 64, Prioritized(priority_exponent=0.6, seed=999),
                MinSize(1))
    dst.load_state_dict(state)
    for _ in range(20):
        a = [(it.key, it.data, prob) for it, prob in src.sample(3)]
        b = [(it.key, it.data, prob) for it, prob in dst.sample(3)]
        assert a == b


def test_uniform_table_roundtrip_draws_identically():
    src = Table("u", 32, Uniform(seed=4), MinSize(1))
    for i in range(12):
        src.insert({"i": i})
    src.sample(4)
    state = src.state_dict()
    dst = Table("u", 32, Uniform(seed=77), MinSize(1))
    dst.load_state_dict(state)
    for _ in range(10):
        a = [it.data["i"] for it, _ in src.sample(2)]
        b = [it.data["i"] for it, _ in dst.sample(2)]
        assert a == b


def test_table_roundtrip_restores_limiter_accounting_and_keys():
    src = Table("t", 16, Uniform(0), MinSize(2))
    keys = [src.insert(i) for i in range(6)]
    src.sample(3)
    state = src.state_dict()
    dst = Table("t", 16, Uniform(0), MinSize(2))
    dst.load_state_dict(state)
    assert dst.size() == 6
    assert dst.rate_limiter.inserts == src.rate_limiter.inserts == 6
    assert dst.rate_limiter.samples == src.rate_limiter.samples == 3
    # key allocation continues where the original left off
    assert dst.insert("fresh") == keys[-1] + 1


def test_sharded_replay_roundtrip_continues_routing():
    src = make_replay_shards(
        lambda: Table("s", 32, Uniform(seed=2), MinSize(1)), 2)
    # an ODD count: a fresh router's cursor (0) and the restored cursor (9)
    # disagree on which shard gets the next insert
    for i in range(9):
        src.insert(i)
    state = src.state_dict()
    dst = make_replay_shards(
        lambda: Table("s", 32, Uniform(seed=5), MinSize(1)), 2)
    dst.load_state_dict(state)
    assert dst.size() == src.size() == 9
    # round-robin cursors restored: the next insert lands on the same shard
    src.insert("next")
    dst.insert("next")
    assert [s.size() for s in src.shards] == [s.size() for s in dst.shards]


def test_sharded_replay_roundtrip_rejects_shard_mismatch():
    src = make_replay_shards(
        lambda: Table("s", 8, Uniform(0), MinSize(1)), 2)
    dst = make_replay_shards(
        lambda: Table("s", 8, Uniform(0), MinSize(1)), 3)
    with pytest.raises(ValueError):
        dst.load_state_dict(src.state_dict())


# ======================================================== RunCheckpointer
def _learner_state(x=1.0):
    import jax.numpy as jnp
    return {"params": {"w": jnp.full((2, 2), x)}, "steps": jnp.asarray(3)}


def test_run_checkpointer_roundtrip(tmp_path):
    ck = RunCheckpointer(str(tmp_path))
    table = Table("t", 16, Uniform(0), MinSize(1))
    for i in range(4):
        table.insert(i)
    ck.save(7, _learner_state(2.5), replay=table.state_dict(),
            counts={"actor_steps": 40.0},
            run_state={"episodes_done": 4},
            meta={"mode": "test"})
    snap = RunCheckpointer(str(tmp_path)).restore(_learner_state(0.0))
    assert snap.step == 7
    np.testing.assert_allclose(np.asarray(snap.learner_state["params"]["w"]),
                               2.5)
    assert snap.counts == {"actor_steps": 40.0}
    assert snap.run_state == {"episodes_done": 4}
    assert snap.meta == {"mode": "test"}
    restored = Table("t", 16, Uniform(0), MinSize(1))
    restored.load_state_dict(snap.replay)
    assert restored.size() == 4


def test_run_checkpointer_empty_returns_none(tmp_path):
    assert RunCheckpointer(str(tmp_path)).restore(_learner_state()) is None


def test_run_checkpointer_gc_keeps_recent(tmp_path):
    ck = RunCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _learner_state(float(step)))
    assert ck.list_steps() == [3, 4]
    assert ck.latest_step() == 4
    snap = ck.restore(_learner_state())
    assert snap.step == 4


def test_run_checkpointer_missing_component_raises(tmp_path):
    from repro.checkpoint import CheckpointError
    ck = RunCheckpointer(str(tmp_path))
    table = Table("t", 8, Uniform(0), MinSize(1))
    table.insert(1)
    ck.save(3, _learner_state(), replay=table.state_dict())
    os.unlink(tmp_path / "replay_3.pkl")
    with pytest.raises(CheckpointError, match="replay"):
        ck.restore(_learner_state())


# ============================================== supervisor classification
def test_classify_exit():
    assert classify_exit(0) == SHUTDOWN
    assert classify_exit(1) == CRASH
    assert classify_exit(42) == CRASH
    assert classify_exit(-signal.SIGKILL) == PREEMPTED
    assert classify_exit(-signal.SIGTERM) == PREEMPTED
    # a death observed during an orderly stop is never an incident
    assert classify_exit(1, stopping=True) == SHUTDOWN


def test_restart_policy_backoff_and_budget():
    policy = RestartPolicy(max_restarts=3, backoff_base_s=0.1,
                           backoff_factor=2.0, backoff_max_s=0.5)
    assert [policy.backoff(i) for i in range(4)] == [0.1, 0.2, 0.4, 0.5]
    assert policy.should_restart(CRASH, 2)
    assert not policy.should_restart(CRASH, 3)       # budget exhausted
    assert not policy.should_restart(SHUTDOWN, 0)    # clean exits stay down
    crash_only = RestartPolicy(restart_on=(CRASH,))
    assert not crash_only.should_restart(PREEMPTED, 0)


def test_restart_policy_validation():
    with pytest.raises(ValueError):
        RestartPolicy(max_restarts=-1)
    with pytest.raises(ValueError):
        RestartPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RestartPolicy(restart_on=("sigsegv",))


# ======================================================== chaos schedules
def test_chaos_policy_schedules_targets_only():
    policy = ChaosPolicy(kill_after_steps=10, kill_targets=("actor/0",),
                         kill_jitter_steps=5, seed=3)
    sched = policy.schedule_for("actor/0")
    assert sched is not None
    assert 10 <= sched.kill_step <= 15
    # deterministic: the same (seed, node) always jitters identically
    assert policy.schedule_for("actor/0").kill_step == sched.kill_step
    assert policy.schedule_for("actor/1") is None
    assert ChaosPolicy().schedule_for("actor/0") is None


def test_chaos_policy_validation():
    with pytest.raises(ValueError):
        ChaosPolicy(kill_after_steps=0)
    with pytest.raises(ValueError):
        ChaosPolicy(rpc_drop_rate=1.0)
    with pytest.raises(ValueError):
        ChaosPolicy(kill_exit_code=0)


def test_kill_schedule_disarms_after_max_kills(monkeypatch):
    from repro.resilience.chaos import RESTARTS_ENV

    class _Actor:
        def observe(self):
            return "ok"

    sched = KillSchedule("actor/0", kill_step=100, exit_code=42, max_kills=1)
    monkeypatch.setenv(RESTARTS_ENV, "0")
    assert sched.armed
    wrapped = KillSchedule("actor/0", 100, 42, 1).wrap(_Actor())
    assert wrapped.observe() == "ok"      # counts but far from kill_step
    monkeypatch.setenv(RESTARTS_ENV, "1")
    assert not sched.armed
    # a disarmed schedule returns the bare actor — no kill machinery left
    bare = _Actor()
    assert KillSchedule("actor/0", 100, 42, 1).wrap(bare) is bare


def test_rpc_injector_counts_faults():
    inj = RPCChaosInjector(drop_rate=0.9, seed=0)
    drops = 0
    for _ in range(30):
        try:
            inj.before_send()
        except ConnectionError:
            drops += 1
    assert drops == inj.injected["drops"] > 20


# ================================================== courier chaos + retry
class _Stats:
    def size(self):
        return 123


def test_courier_retries_through_injected_drops():
    """Idempotent RPCs ride through injected connection drops: the client
    retries (3 attempts) and every call still succeeds.  Seed 0 at rate
    0.3 never drops three times in a row within this window (10 drops in
    40 calls), so the test is deterministic."""
    from repro.distributed import courier

    server, handle = courier.serve(_Stats(), interface=("size",),
                                   name="stats")
    inj = RPCChaosInjector(drop_rate=0.3, seed=0)
    courier.set_rpc_chaos(inj)
    try:
        for _ in range(40):
            assert handle.size() == 123
        assert inj.injected["drops"] >= 5
    finally:
        courier.set_rpc_chaos(None)
        server.stop()


# ==================================================== elastic supervision
class _Reports:
    """Service the workers report lives into."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def put(self, value):
        with self._lock:
            self._items.append(value)

    def items(self):
        with self._lock:
            return list(self._items)


class _CrashOnce:
    """Worker: first life crashes hard; the respawn reports and exits."""

    def __init__(self, reports, exit_code=42):
        from repro.resilience.chaos import worker_restarts
        self.reports = reports
        self.exit_code = exit_code
        self.restarts = worker_restarts()

    def run(self):
        if self.restarts == 0:
            os._exit(self.exit_code)
        self.reports.put(f"alive after {self.restarts} restart")

    def stop(self):
        pass


class _PreemptOnce(_CrashOnce):
    """First life dies by signal (preemption); the respawn reports."""

    def run(self):
        if self.restarts == 0:
            os.kill(os.getpid(), signal.SIGKILL)
        self.reports.put("survived preemption")


class _AlwaysCrash:
    def __init__(self):
        pass

    def run(self):
        os._exit(7)

    def stop(self):
        pass


def _elastic_program(worker_cls, policy, **worker_kwargs):
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("elastic")
    program.restart_policy = policy
    reports = program.add_node("reports", _Reports, role="service",
                               interface=("put", "items"))
    program.add_node("worker", worker_cls, reports, role="worker",
                     **worker_kwargs)
    return program, MultiprocessLauncher(program)


def _wait_for(predicate, timeout=JOIN_S):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(0.1)
    return False


def test_supervisor_respawns_crashed_worker():
    program, launcher = _elastic_program(_CrashOnce,
                                         RestartPolicy(max_restarts=2))
    launcher.launch()
    try:
        assert _wait_for(
            lambda: program.resolve("reports").items()), \
            f"respawned worker never reported; {launcher.restart_stats()}"
    finally:
        launcher.stop()
        launcher.join(timeout=JOIN_S)
    stats = launcher.restart_stats()
    assert stats["restarts"] == {"worker": 1}
    assert stats["exit_kinds"]["worker"][0] == CRASH
    assert program.resolve("reports").items() == ["alive after 1 restart"]


def test_supervisor_respawns_preempted_worker():
    program, launcher = _elastic_program(_PreemptOnce,
                                         RestartPolicy(max_restarts=2))
    launcher.launch()
    try:
        assert _wait_for(lambda: program.resolve("reports").items())
    finally:
        launcher.stop()
        launcher.join(timeout=JOIN_S)
    stats = launcher.restart_stats()
    assert stats["exit_kinds"]["worker"][0] == PREEMPTED
    assert program.resolve("reports").items() == ["survived preemption"]


def test_supervisor_fails_fast_when_budget_exhausted():
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("exhausted")
    program.restart_policy = RestartPolicy(max_restarts=1,
                                           backoff_base_s=0.05)
    program.add_node("worker", _AlwaysCrash, role="worker")
    launcher = MultiprocessLauncher(program).launch()
    with pytest.raises(Exception, match="crash"):
        launcher.join(timeout=JOIN_S)
    # one respawn granted, the second death exhausted the budget
    assert launcher.restart_stats()["restarts"] == {"worker": 1}


def test_no_policy_means_fail_fast():
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("failfast")
    program.add_node("worker", _AlwaysCrash, role="worker")
    launcher = MultiprocessLauncher(program).launch()
    with pytest.raises(Exception, match="crash"):
        launcher.join(timeout=JOIN_S)
    assert launcher.restart_stats()["restarts"] == {}


# ===================================================== config validation
def test_experiment_config_resume_requires_checkpoint_dir():
    from conftest import make_dqn_catch_config
    with pytest.raises(ValueError, match="checkpoint_dir"):
        make_dqn_catch_config(resume=True)


def test_experiment_config_rejects_wrong_resilience_types():
    from conftest import make_dqn_catch_config
    with pytest.raises(ValueError, match="RestartPolicy"):
        make_dqn_catch_config(restart_policy="aggressive")
    with pytest.raises(ValueError, match="ChaosPolicy"):
        make_dqn_catch_config(chaos={"kill": True})


# ============================================ exact resume (single process)
def test_run_experiment_resume_is_bit_exact(tmp_path):
    """The parity pin: 4 episodes + final snapshot, resumed to 8, must be
    bit-identical (params, opt state, counters, train curve) to 8 episodes
    uninterrupted."""
    import jax
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    straight = run_experiment(make_dqn_catch_config(
        seed=3, min_replay_size=10, num_episodes=8, eval_episodes=0))

    cfg = make_dqn_catch_config(seed=3, min_replay_size=10, num_episodes=4,
                                eval_episodes=0,
                                checkpoint_dir=str(tmp_path))
    run_experiment(cfg)
    resumed = run_experiment(dataclasses.replace(cfg, num_episodes=8,
                                                 resume=True))

    assert resumed.learner_steps == straight.learner_steps
    assert resumed.train_returns == straight.train_returns
    assert resumed.actor_steps == straight.actor_steps
    assert resumed.counts == straight.counts
    for a, b in zip(jax.tree_util.tree_leaves(straight.learner.state),
                    jax.tree_util.tree_leaves(resumed.learner.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_experiment_resume_after_sigkill_is_bit_exact(tmp_path):
    """Kill-and-restart parity: a run hard-killed mid-training (os._exit
    from inside the train loop — no cleanup, no final save) resumes from
    its last cadence checkpoint to a state bit-identical to a run that was
    never interrupted."""
    import jax
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    src_dir = os.path.join(os.path.dirname(tests_dir), "src")
    ckpt_dir = tmp_path / "ckpt"
    script = tmp_path / "phase1.py"
    script.write_text(
        "import os, sys\n"
        f"sys.path.insert(0, {src_dir!r})\n"
        f"sys.path.insert(0, {tests_dir!r})\n"
        "from conftest import make_dqn_catch_config\n"
        "from repro.experiments import run_experiment\n"
        "class KillAfter:\n"
        "    def __init__(self, n): self.n = n\n"
        "    def __call__(self, label):\n"
        "        def log(result):\n"
        "            if label != 'train': return\n"
        "            self.n -= 1\n"
        "            if self.n <= 0: os._exit(9)\n"
        "        return log\n"
        "cfg = make_dqn_catch_config(\n"
        "    seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0,\n"
        f"    checkpoint_dir={str(ckpt_dir)!r}, checkpoint_every=1,\n"
        "    logger_factory=KillAfter(6))\n"
        "run_experiment(cfg)\n"
        "raise SystemExit('unreachable: the kill never fired')\n")
    proc = subprocess.run([sys.executable, str(script)], timeout=300,
                          capture_output=True, text=True)
    assert proc.returncode == 9, proc.stderr
    assert (ckpt_dir / "run_latest.json").exists()

    resumed = run_experiment(make_dqn_catch_config(
        seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0,
        checkpoint_dir=str(ckpt_dir), checkpoint_every=1, resume=True))
    straight = run_experiment(make_dqn_catch_config(
        seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0))

    assert resumed.learner_steps == straight.learner_steps
    assert resumed.train_returns == straight.train_returns
    assert resumed.counts == straight.counts
    for a, b in zip(jax.tree_util.tree_leaves(straight.learner.state),
                    jax.tree_util.tree_leaves(resumed.learner.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_experiment_final_save_dedupes_against_cadence(tmp_path,
                                                          monkeypatch):
    """Satellite: with a per-episode cadence the final checkpoint is the
    cadence checkpoint — run_experiment must not write it twice."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment
    from repro.resilience import run_checkpoint

    saves = []
    original = run_checkpoint.RunCheckpointer.save

    def counting_save(self, step, learner_state, **kwargs):
        saves.append(int(step))
        return original(self, step, learner_state, **kwargs)

    monkeypatch.setattr(run_checkpoint.RunCheckpointer, "save",
                        counting_save)
    result = run_experiment(make_dqn_catch_config(
        seed=0, min_replay_size=10, num_episodes=6, eval_episodes=0,
        checkpoint_dir=str(tmp_path), checkpoint_every=1))
    assert saves, "cadence checkpoints never fired"
    # the last cadence save captured the final step; no duplicate final save
    assert saves[-1] == result.learner_steps
    assert len(saves) == len(set(saves))

    # cadence off -> exactly one (final) save
    saves.clear()
    run_experiment(make_dqn_catch_config(
        seed=0, min_replay_size=10, num_episodes=3, eval_episodes=0,
        checkpoint_dir=str(tmp_path / "b")))
    assert len(saves) == 1


# ================================================ distributed resume/chaos
def test_run_distributed_experiment_resumes_counts(tmp_path):
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    cfg = make_dqn_catch_config(seed=0, min_replay_size=20, eval_episodes=0,
                                checkpoint_dir=str(tmp_path))
    first = run_distributed_experiment(cfg, num_actors=2,
                                       max_actor_steps=300, timeout_s=90)
    assert (tmp_path / "run_latest.json").exists()
    first_steps = int(first.counts["actor_steps"])

    # Doctor the snapshot with a sentinel count: seeing it in the resumed
    # result proves the restore path ran end-to-end (snapshot -> restore
    # callback -> counter), without racing the actors' fresh progress.
    ck = RunCheckpointer(str(tmp_path))
    snap = ck.restore(first.learner.state)
    counts = dict(snap.counts)
    counts["resume_sentinel"] = 123.0
    ck.save(snap.step, snap.learner_state, replay=snap.replay, counts=counts)

    resumed = run_distributed_experiment(
        dataclasses.replace(cfg, resume=True), num_actors=2,
        max_actor_steps=first_steps + 50, timeout_s=90)
    assert resumed.counts.get("resume_sentinel") == 123.0
    assert resumed.counts["actor_steps"] >= first_steps + 50
    # learner state restored: its step counter continues, never resets
    assert resumed.learner_steps >= first.learner_steps


@pytest.mark.slow
def test_chaos_acceptance_kill_actor_still_learns():
    """Acceptance: a seeded chaos kill takes down an actor mid-training on
    DQN-on-Catch (multiprocess); the supervisor classifies the crash,
    respawns the replica (which disarms), and the run still reaches the
    learning threshold."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher="multiprocess",
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=400, kill_targets=("actor/0",),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000, timeout_s=240)
    assert result.counts.get("actor_steps", 0) >= 4000
    resilience = result.extras["resilience"]
    assert resilience["restarts"].get("actor/0") == 1, resilience
    assert CRASH in resilience["exit_kinds"]["actor/0"]
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6


# ========================================== PR 9: jittered backoff / retry
def test_backoff_policy_deterministic_when_unjittered():
    from repro.distributed import BackoffPolicy
    policy = BackoffPolicy(base_s=0.1, factor=2.0, max_s=0.5, jitter=0.0)
    assert [policy.delay(a) for a in range(4)] == [0.1, 0.2, 0.4, 0.5]


def test_backoff_policy_jitter_stays_in_band():
    import random

    from repro.distributed import BackoffPolicy
    policy = BackoffPolicy(base_s=0.1, factor=2.0, max_s=1.0, jitter=0.5)
    rng = random.Random(0)
    for attempt in range(5):
        full = min(0.1 * 2.0 ** attempt, 1.0)
        for _ in range(20):
            d = policy.delay(attempt, rng=rng)
            assert full * 0.5 <= d <= full


def test_backoff_policy_validation():
    from repro.distributed import BackoffPolicy
    with pytest.raises(ValueError):
        BackoffPolicy(base_s=-1.0)
    with pytest.raises(ValueError):
        BackoffPolicy(factor=0.5)
    with pytest.raises(ValueError):
        BackoffPolicy(jitter=1.5)


def test_retry_config_validation_and_install():
    from repro.distributed import BackoffPolicy, RetryConfig, set_retry_config
    from repro.distributed import courier

    with pytest.raises(ValueError):
        RetryConfig(max_attempts=0)
    with pytest.raises(ValueError):
        RetryConfig(reconnect_deadline_s=0.0)
    with pytest.raises(TypeError):
        RetryConfig(backoff="fast")

    custom = RetryConfig(max_attempts=5, reconnect_deadline_s=9.0,
                         backoff=BackoffPolicy(base_s=0.01))
    try:
        set_retry_config(custom)
        assert courier.retry_config() is custom
        with pytest.raises(TypeError):
            set_retry_config("nope")
    finally:
        set_retry_config(None)
    assert courier.retry_config().max_attempts == 3   # defaults restored


# ====================================== PR 9: reconnecting courier clients
def _serve_stats():
    from repro.distributed import courier

    class _Target:
        def __init__(self):
            self.values = []

        def size(self):            # idempotent (IDEMPOTENT_METHODS)
            return len(self.values)

        def put(self, v):          # non-idempotent
            self.values.append(v)
            return v

    target = _Target()
    server, handle = courier.serve(target, interface=("size", "put"),
                                   name="failover_stats")
    return target, server, handle


def test_remote_handle_raises_service_unavailable_after_deadline():
    import socket as _socket

    from repro.distributed import (BackoffPolicy, RetryConfig,
                                   ServiceUnavailable, set_retry_config)
    from repro.distributed.courier import RemoteHandle

    probe = _socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()    # nobody listens here now
    handle = RemoteHandle(("127.0.0.1", port), name="gone",
                          interface=("size",))
    set_retry_config(RetryConfig(
        reconnect_deadline_s=0.3,
        backoff=BackoffPolicy(base_s=0.02, max_s=0.05)))
    try:
        t0 = time.monotonic()
        with pytest.raises(ServiceUnavailable, match="unreachable"):
            handle.size()
        elapsed = time.monotonic() - t0
        assert 0.25 <= elapsed < 5.0, elapsed
    finally:
        set_retry_config(None)
    # ServiceUnavailable IS a ConnectionError: workers catch one type
    assert issubclass(ServiceUnavailable, ConnectionError)


def test_remote_handle_reconnects_through_restart_window():
    """A server stop + same-address re-bind mid-call must be invisible to
    the client — for an idempotent AND a non-idempotent method (the frame
    died before a single response byte, so the handler never ran)."""
    from repro.distributed import BackoffPolicy, RetryConfig, set_retry_config
    from repro.distributed.courier import Server

    target, server, handle = _serve_stats()
    assert handle.put("a") == "a"     # cache a live connection
    address, authkey = server.address, server.authkey
    server.stop()

    replacement = {}

    def rebind():
        time.sleep(0.3)
        replacement["server"] = Server(
            target, interface=("size", "put"), name="failover_stats",
            host=address[0], port=address[1], authkey=authkey).start()

    threading.Thread(target=rebind, daemon=True).start()
    set_retry_config(RetryConfig(
        reconnect_deadline_s=10.0,
        backoff=BackoffPolicy(base_s=0.02, max_s=0.1)))
    try:
        assert handle.put("b") == "b"        # non-idempotent, stale socket
        assert handle.size() == 2            # idempotent, fresh socket
        assert target.values == ["a", "b"]   # executed exactly once
    finally:
        set_retry_config(None)
        replacement["server"].stop()


def test_auth_failure_fast_fails_without_reconnect_retries():
    from repro.distributed import courier
    from repro.distributed.courier import RemoteHandle

    target, server, _ = _serve_stats()
    bad = RemoteHandle(server.address, name="failover_stats",
                       interface=("size",), authkey=b"wrong")
    try:
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="authentication"):
            bad.size()
        # a wrong key is not transient: no 5s reconnect window burned
        assert time.monotonic() - t0 < 2.0
    finally:
        server.stop()


# ==================================== PR 9: straggler-tolerant ParameterServer
def _ps_state(x):
    return {"w": np.float32(x)}


def test_param_server_quorum_merges_on_timeout():
    from repro.learners import ParameterServer

    ps = ParameterServer(2, 1, barrier_timeout_s=0.15, min_quorum=1)
    t0 = time.monotonic()
    merged = ps.sync(0, _ps_state(2.0))   # replica 1 never shows up
    elapsed = time.monotonic() - t0
    assert merged == {"w": np.float32(2.0)}
    assert elapsed >= 0.15
    stats = ps.stats()
    assert stats["rounds"] == 1
    assert stats["quorum_merges"] == 1
    assert stats["min_quorum"] == 1


def test_param_server_quorum_full_round_merges_immediately():
    from repro.learners import ParameterServer

    ps = ParameterServer(2, 1, barrier_timeout_s=5.0, min_quorum=1)
    results = {}

    def contribute(rid, x):
        results[rid] = ps.sync(rid, _ps_state(x))

    t = threading.Thread(target=contribute, args=(0, 1.0))
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    contribute(1, 3.0)
    t.join(JOIN_S)
    # the full round closed on arrival, NOT after the 5s timeout
    assert time.monotonic() - t0 < 1.0
    assert results[0] == results[1] == {"w": np.float32(2.0)}
    assert ps.stats()["quorum_merges"] == 0


def test_param_server_late_replica_adopts_instead_of_remerging():
    """PR 10 regression (quorum double-merge fix): a straggler that missed
    a merge must ADOPT the blend, not open a lone round with its stale
    state — which previously REPLACED the merged params with pre-merge
    ones."""
    from repro.learners import ParameterServer

    ps = ParameterServer(2, 1, barrier_timeout_s=0.1, min_quorum=1)
    assert ps.sync(0, _ps_state(1.0)) == {"w": np.float32(1.0)}
    # the straggler arrives after its round merged without it: its state
    # predates the blend, so it adopts rather than contributes
    assert ps.sync(1, _ps_state(9.0)) == {"w": np.float32(1.0)}
    assert ps.rounds == 1
    assert ps.stats()["stale_adoptions"] == 1
    assert ps.merged == {"w": np.float32(1.0)}
    # next period it contributes fresh work: round 2 times out and merges
    # the straggler's NEW state (the only pending contribution)
    assert ps.sync(1, _ps_state(5.0)) == {"w": np.float32(5.0)}
    assert ps.rounds == 2


def test_param_server_quorum_round_merges_once_not_twice():
    """PR 10 regression: exactly ``min_quorum`` contributions arriving just
    under ``barrier_timeout_s`` merge ONCE — the straggler that shows up
    after the deadline adopts, and the blend is untouched."""
    from repro.learners import ParameterServer

    ps = ParameterServer(3, 1, barrier_timeout_s=0.4, min_quorum=2)
    results = {}

    def contribute(rid, x):
        results[rid] = ps.sync(rid, _ps_state(x))

    t0 = threading.Thread(target=contribute, args=(0, 1.0))
    t0.start()
    time.sleep(0.3)                    # just under the 0.4s deadline
    t1 = threading.Thread(target=contribute, args=(1, 3.0))
    t1.start()
    t0.join(JOIN_S)
    t1.join(JOIN_S)
    assert not t0.is_alive() and not t1.is_alive()
    # ONE timed-out merge of the two arrivals — not one per waiter
    assert results[0] == results[1] == {"w": np.float32(2.0)}
    stats = ps.stats()
    assert stats["rounds"] == 1
    assert stats["quorum_merges"] == 1
    # the replica that missed the round adopts the blend verbatim
    assert ps.sync(2, _ps_state(9.0)) == {"w": np.float32(2.0)}
    assert ps.rounds == 1
    assert ps.stats()["stale_adoptions"] == 1


def test_param_server_invalidate_withdraws_parked_contribution():
    """PR 10 regression: a restored replica's stale ``replica_id`` cannot
    double-contribute to one round.  ``invalidate`` releases its parked
    sync with ``None`` (nothing adopted over the restored state) and drops
    the stale value, so the round that eventually merges holds only fresh
    contributions."""
    from repro.learners import ParameterServer

    ps = ParameterServer(2, 1, barrier_timeout_s=30.0, min_quorum=2)
    out = {}

    def parked():
        out["r"] = ps.sync(0, _ps_state(666.0))   # pre-kill stale state

    t = threading.Thread(target=parked)
    t.start()
    time.sleep(0.1)
    ps.invalidate(0)                   # replica 0 dies; watchdog restores it
    t.join(JOIN_S)
    assert not t.is_alive()
    assert out["r"] is None            # withdrawn, not adopted
    assert ps.rounds == 0

    # the restored replica re-contributes cleanly; the stale 666 is gone
    results = {}

    def contribute(rid, x):
        results[rid] = ps.sync(rid, _ps_state(x))

    t0 = threading.Thread(target=contribute, args=(0, 2.0))
    t0.start()
    contribute(1, 4.0)
    t0.join(JOIN_S)
    assert results[0] == results[1] == {"w": np.float32(3.0)}
    assert ps.rounds == 1


def test_worker_mark_down_invalidates_parked_contribution():
    """``LearnerReplicaWorker.mark_down`` must withdraw the replica's
    pending contribution at the server — a dead replica's stale state must
    not be folded into a round it no longer stands behind."""
    from repro.learners import LearnerReplicaWorker

    class _Recorder:
        def __init__(self):
            self.invalidated = []

        def invalidate(self, replica_id):
            self.invalidated.append(replica_id)

    recorder = _Recorder()
    worker = LearnerReplicaWorker(learner=None, param_server=recorder,
                                  replica_id=3)
    worker.mark_down()
    assert recorder.invalidated == [3]
    worker.mark_up()


def test_param_server_default_barrier_still_blocks():
    """No quorum knobs -> the strict all-or-nothing barrier of PR 6."""
    from repro.learners import ParameterServer

    ps = ParameterServer(2, 1)
    done = threading.Event()

    def first():
        ps.sync(0, _ps_state(1.0))
        done.set()

    threading.Thread(target=first, daemon=True).start()
    assert not done.wait(0.4), "strict barrier released with 1/2 replicas"
    assert ps.sync(1, _ps_state(3.0)) == {"w": np.float32(2.0)}
    assert done.wait(JOIN_S)
    assert "quorum_merges" not in ps.stats()


def test_param_server_quorum_validation():
    from repro.learners import ParameterServer

    with pytest.raises(ValueError, match="barrier_timeout_s"):
        ParameterServer(2, 1, barrier_timeout_s=0.0)
    with pytest.raises(ValueError, match="min_quorum"):
        ParameterServer(2, 1, min_quorum=1)           # timeout missing
    with pytest.raises(ValueError, match="min_quorum"):
        ParameterServer(2, 1, barrier_timeout_s=1.0, min_quorum=3)


def test_experiment_config_validates_quorum_and_retry():
    from conftest import make_dqn_catch_config
    with pytest.raises(ValueError, match="barrier_timeout_s"):
        make_dqn_catch_config(min_quorum=1)
    with pytest.raises(ValueError, match="rpc_retry"):
        make_dqn_catch_config(rpc_retry="fast")
    with pytest.raises(ValueError, match="service_snapshot_period_s"):
        make_dqn_catch_config(service_snapshot_period_s=0.0)


# ============================================== PR 9: simulated service death
def test_table_mark_down_blocks_data_path_not_control_path():
    from repro.distributed import ServiceUnavailable

    table = Table("t", 16, Uniform(0), MinSize(1))
    table.insert("x")
    table.mark_down()
    with pytest.raises(ServiceUnavailable, match="down"):
        table.insert("y")
    with pytest.raises(ServiceUnavailable, match="down"):
        table.sample(1)
    with pytest.raises(ServiceUnavailable, match="down"):
        table.update_priorities([0], [1.0])
    # the watchdog, telemetry probes, and checkpointer still need these
    assert table.size() == 1
    state = table.state_dict()
    table.mark_up()
    table.insert("y")
    assert table.size() == 2
    restored = Table("t", 16, Uniform(0), MinSize(1))
    restored.load_state_dict(state)
    assert restored.size() == 1


def test_counter_recoverable_roundtrip():
    from repro.core.loop import Counter
    from repro.resilience.failover import is_recoverable, service_activity

    counter = Counter()
    counter.increment(actor_steps=10, episodes=2)
    assert is_recoverable(counter)
    state = counter.state_dict()
    restored = Counter()
    restored.load_state_dict(state)
    assert restored.get_counts() == counter.get_counts()
    assert service_activity(counter) == 12


def test_sharded_replay_shard_failover_matches_uninterrupted():
    """Kill + snapshot-restore of one shard leaves the sharded service in
    lock-step with a never-interrupted twin: same global keys, the same
    sample stream, the same priorities (satellite d)."""
    def build():
        return make_replay_shards(
            lambda: Table("s", 64, Prioritized(0.6, seed=3), MinSize(1)), 2)

    live, ref = build(), build()
    for i in range(12):
        assert live.insert(i, priority=1.0 + i) \
            == ref.insert(i, priority=1.0 + i)

    shard = live.shards[0]
    state = shard.state_dict()
    shard.mark_down()
    from repro.distributed import ServiceUnavailable
    with pytest.raises(ServiceUnavailable):
        shard.insert("lost")
    shard.load_state_dict(state)
    shard.mark_up()

    # identical op streams from here on: inserts route to the same shards
    # with the same global keys (k * num_shards + shard index) ...
    for i in range(12, 20):
        assert live.insert(i, priority=0.5) == ref.insert(i, priority=0.5)
    # ... priorities update through the same routing ...
    keys = [0, 1, 2, 3]
    live.update_priorities(keys, [9.0, 8.0, 7.0, 6.0])
    ref.update_priorities(keys, [9.0, 8.0, 7.0, 6.0])
    # ... and the interleaved sample streams stay identical
    for _ in range(15):
        a = [(it.key, it.data, prob) for it, prob in live.sample(3)]
        b = [(it.key, it.data, prob) for it, prob in ref.sample(3)]
        assert a == b


# =============================================== PR 9: telemetry hardening
def test_metrics_pusher_survives_dead_hub_and_recovers():
    from repro.telemetry import registry as _registry
    from repro.telemetry.hub import MetricsHub, MetricsPusher

    class _FlakyHub:
        def __init__(self, failures):
            self.failures = failures
            self.hub = MetricsHub()

        def push(self, node, snapshot):
            if self.failures > 0:
                self.failures -= 1
                raise ConnectionError("hub is restarting")
            return self.hub.push(node, snapshot)

    _registry.configure(enabled=True, node="pusher_test")
    flaky = _FlakyHub(failures=3)
    pusher = MetricsPusher(flaky, "pusher_test", period_s=0.02).start()
    try:
        assert _wait_for(lambda: flaky.hub.num_pushes() > 0, timeout=10)
    finally:
        pusher.stop()
        _registry.unconfigure()
    # the outage was counted, never fatal, and the hub re-registered us
    assert pusher.push_failures >= 3
    assert "pusher_test" in flaky.hub.nodes()


# ================================================= PR 9: service watchdog
class _FakeLauncher:
    """Just enough launcher surface for a ServiceWatchdog unit test."""

    def __init__(self, servers):
        self._servers = servers
        self.errors = []

    def should_stop(self):
        return False

    def _record_error(self, error):
        self.errors.append(error)


def test_service_watchdog_kill_restores_snapshot_at_same_address(tmp_path):
    from repro.distributed import ServiceUnavailable, courier
    from repro.resilience.failover import ServiceWatchdog

    table = Table("t", 32, Uniform(0), MinSize(1))
    server, handle = courier.serve(
        table, interface=("insert", "sample", "size"), name="replay/shard_0")
    launcher = _FakeLauncher({"replay/shard_0": server})
    wd = ServiceWatchdog(launcher, RestartPolicy(max_restarts=2,
                                                 backoff_base_s=0.05),
                         snapshot_period_s=0.05,
                         snapshot_dir=str(tmp_path))
    wd.register("replay/shard_0", table)
    wd.start()
    try:
        for i in range(5):
            handle.insert(i)
        wd.snapshot_now()          # deterministic cut: 5 items on disk
        table.insert("lost")       # arrives after the snapshot -> rolled back
        wd.kill("replay/shard_0", exit_code=42)
        with pytest.raises(ServiceUnavailable):
            table.insert("down")   # in-parent data path is down too

        assert _wait_for(lambda: launcher._servers["replay/shard_0"]
                         is not server, timeout=JOIN_S), \
            f"service never respawned; errors={launcher.errors}"
        # SAME address: the ORIGINAL pickled handle keeps working
        assert launcher._servers["replay/shard_0"].address == server.address
        assert handle.size() == 5     # restored to the snapshot exactly
        handle.insert("after")        # and writable again
        assert handle.size() == 6
    finally:
        wd.join(timeout=JOIN_S)
        launcher._servers["replay/shard_0"].stop()
    stats = wd.stats()
    assert stats["service_restarts"] == {"replay/shard_0": 1}
    assert stats["service_exit_kinds"]["replay/shard_0"] == [CRASH]
    assert launcher.errors == []


def test_service_watchdog_restores_async_param_service_same_address(
        tmp_path):
    """PR 10: the ``learner/param_service`` node fails over like any other
    service — the watchdog kills it (push/pull raise ``ServiceUnavailable``),
    restores the snapshot's contributions, and re-binds at the SAME courier
    address so the original pickled handle's pulls resume through
    reconnect."""
    from repro.distributed import ServiceUnavailable, courier
    from repro.learners import (ASYNC_PARAM_SERVICE_INTERFACE,
                                AsyncParameterService)
    from repro.resilience.failover import ServiceWatchdog

    service = AsyncParameterService(num_replicas=2, merge="mean")
    server, handle = courier.serve(
        service, interface=ASYNC_PARAM_SERVICE_INTERFACE + ("activity",),
        name="learner/param_service")
    launcher = _FakeLauncher({"learner/param_service": server})
    wd = ServiceWatchdog(launcher, RestartPolicy(max_restarts=2,
                                                 backoff_base_s=0.05),
                         snapshot_period_s=0.05,
                         snapshot_dir=str(tmp_path))
    wd.register("learner/param_service", service)
    wd.start()
    try:
        handle.push(0, _ps_state(2.0), 10)
        handle.push(1, _ps_state(4.0), 10)
        assert handle.pull() == {"w": np.float32(3.0)}
        wd.snapshot_now()              # deterministic cut: both contributions
        service.push(0, _ps_state(100.0), 11)   # post-snapshot -> rolled back
        wd.kill("learner/param_service", exit_code=42)
        with pytest.raises(ServiceUnavailable):
            service.pull()             # in-parent data path is down too

        assert _wait_for(lambda: launcher._servers["learner/param_service"]
                         is not server, timeout=JOIN_S), \
            f"service never respawned; errors={launcher.errors}"
        respawned = launcher._servers["learner/param_service"]
        assert respawned.address == server.address
        # the ORIGINAL handle reconnects; the blend is the snapshot's
        assert handle.pull() == {"w": np.float32(3.0)}
        handle.push(1, _ps_state(6.0), 12)      # and writable again
        assert handle.pull() == {"w": np.float32(4.0)}
    finally:
        wd.join(timeout=JOIN_S)
        launcher._servers["learner/param_service"].stop()
    stats = wd.stats()
    assert stats["service_restarts"] == {"learner/param_service": 1}
    assert launcher.errors == []


@pytest.mark.slow
def test_failover_acceptance_kill_async_param_service_still_learns():
    """Acceptance (PR 10): chaos kills the ``learner/param_service`` node
    mid-run under ``learner_sync="async"``.  The watchdog restores it from
    its snapshot at the same address; replica pushes/pulls resume through
    courier reconnect; no replica or worker dies of ``ServiceUnavailable``;
    and the run still learns."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher="multiprocess",
        num_learner_replicas=2, learner_average_period=10,
        learner_sync="async",
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=20,
                          kill_targets=("learner/param_service",),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000, timeout_s=300)
    assert result.counts.get("actor_steps", 0) >= 4000
    resilience = result.extras["resilience"]
    assert resilience["service_restarts"].get("learner/param_service") == 1, \
        resilience
    # no WORKER died: replicas degraded through the restart window
    assert resilience["restarts"] == {}, resilience
    learners = result.extras["learners"]
    assert learners["sync"] == "async"
    assert learners["rounds"] > 0            # exchanges resumed post-restore
    assert all(s > 0 for s in learners["per_replica_steps"])
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6


def test_service_watchdog_budget_exhaustion_records_error(tmp_path):
    from repro.resilience.failover import ServiceWatchdog

    table = Table("t", 8, Uniform(0), MinSize(1))
    launcher = _FakeLauncher({})
    wd = ServiceWatchdog(launcher, RestartPolicy(max_restarts=1,
                                                 backoff_base_s=0.02),
                         snapshot_period_s=0.05,
                         snapshot_dir=str(tmp_path))
    wd.register("replay", table)
    wd.start()
    try:
        wd.kill("replay", exit_code=42)
        assert _wait_for(lambda: wd.stats()["service_restarts"]
                         .get("replay") == 1, timeout=JOIN_S)
        assert _wait_for(lambda: "replay" not in wd._down, timeout=JOIN_S)
        wd.kill("replay", exit_code=42)   # second death exhausts the budget
        assert _wait_for(lambda: launcher.errors, timeout=JOIN_S)
    finally:
        wd.join(timeout=JOIN_S)
    assert "not restartable" in str(launcher.errors[0])
    assert wd.stats()["service_exit_kinds"]["replay"] == [CRASH, CRASH]


def test_chaos_policy_service_schedules_target_services_only():
    policy = ChaosPolicy(kill_after_steps=100, kill_jitter_steps=10,
                        kill_targets=("replay/shard_0",), seed=7)
    assert policy.service_schedule_for("replay/shard_1") is None
    schedule = policy.service_schedule_for("replay/shard_0")
    assert schedule is not None
    assert 100 <= schedule.kill_step <= 110
    # deterministic per-node jitter: resolving twice gives the same step
    assert policy.service_schedule_for("replay/shard_0").kill_step \
        == schedule.kill_step
    assert schedule.fired == 0
    # services that cannot mark_down are rejected as kill targets
    from repro.resilience.failover import ServiceWatchdog

    class _NoDown:
        def state_dict(self):
            return {}

        def load_state_dict(self, state):
            pass

    wd = ServiceWatchdog(_FakeLauncher({}), RestartPolicy(), chaos=policy)
    with pytest.raises(ValueError, match="mark_down"):
        wd.register("replay/shard_0", _NoDown())


@pytest.mark.slow
def test_failover_acceptance_kill_shard_and_replica_still_learns():
    """Acceptance (PR 9): chaos kills BOTH a replay shard and a learner
    replica mid-training.  The watchdog restores each from its snapshot
    and re-binds its server; no worker dies of ``ServiceUnavailable``;
    quorum keeps averaging rounds completing; and the run still learns."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher="multiprocess",
        num_learner_replicas=2, learner_average_period=10,
        barrier_timeout_s=2.0, min_quorum=1,
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=300,
                          kill_targets=("replay/shard_0",
                                        "learner/replica_0"),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000, timeout_s=300)
    assert result.counts.get("actor_steps", 0) >= 4000
    resilience = result.extras["resilience"]
    assert resilience["service_restarts"].get("replay/shard_0") == 1, \
        resilience
    assert resilience["service_restarts"].get("learner/replica_0") == 1, \
        resilience
    assert CRASH in resilience["service_exit_kinds"]["replay/shard_0"]
    assert CRASH in resilience["service_exit_kinds"]["learner/replica_0"]
    # no WORKER died: actors absorbed the outage with skipped adds
    assert resilience["restarts"] == {}, resilience
    # averaging kept going through the replica outage
    assert result.extras["learners"]["rounds"] > 0
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6
