"""repro.resilience: exact-resume checkpointing, elastic worker pools, and
the chaos-injection harness.

Three layers under test:

- state round-trips — replay tables (selector internals verbatim, so the
  restored table draws the SAME sample sequence), the run-wide
  ``RunCheckpointer`` manifest protocol, and the kill-and-restart parity
  pin (a SIGKILLed single-process run, resumed, is bit-identical to an
  uninterrupted one);
- elastic supervision — the multiprocess launcher classifies worker deaths
  (crash / preemption / shutdown), respawns within the restart budget, and
  fails fast once it is exhausted;
- chaos — seeded kill schedules and courier RPC fault injection, ending in
  the acceptance test that kills an actor mid-training and still learns.

Worker/service classes are module-level so the multiprocess backend can
pickle them into spawn children.
"""
import dataclasses
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.replay import (MinSize, Prioritized, Table, Uniform,
                          make_replay_shards)
from repro.resilience import (CRASH, PREEMPTED, SHUTDOWN, ChaosPolicy,
                              KillSchedule, RestartPolicy, RPCChaosInjector,
                              RunCheckpointer, classify_exit)

JOIN_S = 60


# ===================================================== replay round-trips
def test_prioritized_table_roundtrip_draws_identically():
    """The restored table must continue the EXACT sample stream of the
    original — sum-tree array and RNG restored verbatim, even into a table
    constructed with a different selector seed."""
    src = Table("p", 64, Prioritized(priority_exponent=0.6, seed=1),
                MinSize(1))
    rng = np.random.RandomState(0)
    for i in range(20):
        src.insert(i, priority=float(rng.rand()) + 0.1)
    for _ in range(5):
        src.sample(2)
    state = src.state_dict()

    dst = Table("p", 64, Prioritized(priority_exponent=0.6, seed=999),
                MinSize(1))
    dst.load_state_dict(state)
    for _ in range(20):
        a = [(it.key, it.data, prob) for it, prob in src.sample(3)]
        b = [(it.key, it.data, prob) for it, prob in dst.sample(3)]
        assert a == b


def test_uniform_table_roundtrip_draws_identically():
    src = Table("u", 32, Uniform(seed=4), MinSize(1))
    for i in range(12):
        src.insert({"i": i})
    src.sample(4)
    state = src.state_dict()
    dst = Table("u", 32, Uniform(seed=77), MinSize(1))
    dst.load_state_dict(state)
    for _ in range(10):
        a = [it.data["i"] for it, _ in src.sample(2)]
        b = [it.data["i"] for it, _ in dst.sample(2)]
        assert a == b


def test_table_roundtrip_restores_limiter_accounting_and_keys():
    src = Table("t", 16, Uniform(0), MinSize(2))
    keys = [src.insert(i) for i in range(6)]
    src.sample(3)
    state = src.state_dict()
    dst = Table("t", 16, Uniform(0), MinSize(2))
    dst.load_state_dict(state)
    assert dst.size() == 6
    assert dst.rate_limiter.inserts == src.rate_limiter.inserts == 6
    assert dst.rate_limiter.samples == src.rate_limiter.samples == 3
    # key allocation continues where the original left off
    assert dst.insert("fresh") == keys[-1] + 1


def test_sharded_replay_roundtrip_continues_routing():
    src = make_replay_shards(
        lambda: Table("s", 32, Uniform(seed=2), MinSize(1)), 2)
    # an ODD count: a fresh router's cursor (0) and the restored cursor (9)
    # disagree on which shard gets the next insert
    for i in range(9):
        src.insert(i)
    state = src.state_dict()
    dst = make_replay_shards(
        lambda: Table("s", 32, Uniform(seed=5), MinSize(1)), 2)
    dst.load_state_dict(state)
    assert dst.size() == src.size() == 9
    # round-robin cursors restored: the next insert lands on the same shard
    src.insert("next")
    dst.insert("next")
    assert [s.size() for s in src.shards] == [s.size() for s in dst.shards]


def test_sharded_replay_roundtrip_rejects_shard_mismatch():
    src = make_replay_shards(
        lambda: Table("s", 8, Uniform(0), MinSize(1)), 2)
    dst = make_replay_shards(
        lambda: Table("s", 8, Uniform(0), MinSize(1)), 3)
    with pytest.raises(ValueError):
        dst.load_state_dict(src.state_dict())


# ======================================================== RunCheckpointer
def _learner_state(x=1.0):
    import jax.numpy as jnp
    return {"params": {"w": jnp.full((2, 2), x)}, "steps": jnp.asarray(3)}


def test_run_checkpointer_roundtrip(tmp_path):
    ck = RunCheckpointer(str(tmp_path))
    table = Table("t", 16, Uniform(0), MinSize(1))
    for i in range(4):
        table.insert(i)
    ck.save(7, _learner_state(2.5), replay=table.state_dict(),
            counts={"actor_steps": 40.0},
            run_state={"episodes_done": 4},
            meta={"mode": "test"})
    snap = RunCheckpointer(str(tmp_path)).restore(_learner_state(0.0))
    assert snap.step == 7
    np.testing.assert_allclose(np.asarray(snap.learner_state["params"]["w"]),
                               2.5)
    assert snap.counts == {"actor_steps": 40.0}
    assert snap.run_state == {"episodes_done": 4}
    assert snap.meta == {"mode": "test"}
    restored = Table("t", 16, Uniform(0), MinSize(1))
    restored.load_state_dict(snap.replay)
    assert restored.size() == 4


def test_run_checkpointer_empty_returns_none(tmp_path):
    assert RunCheckpointer(str(tmp_path)).restore(_learner_state()) is None


def test_run_checkpointer_gc_keeps_recent(tmp_path):
    ck = RunCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _learner_state(float(step)))
    assert ck.list_steps() == [3, 4]
    assert ck.latest_step() == 4
    snap = ck.restore(_learner_state())
    assert snap.step == 4


def test_run_checkpointer_missing_component_raises(tmp_path):
    from repro.checkpoint import CheckpointError
    ck = RunCheckpointer(str(tmp_path))
    table = Table("t", 8, Uniform(0), MinSize(1))
    table.insert(1)
    ck.save(3, _learner_state(), replay=table.state_dict())
    os.unlink(tmp_path / "replay_3.pkl")
    with pytest.raises(CheckpointError, match="replay"):
        ck.restore(_learner_state())


# ============================================== supervisor classification
def test_classify_exit():
    assert classify_exit(0) == SHUTDOWN
    assert classify_exit(1) == CRASH
    assert classify_exit(42) == CRASH
    assert classify_exit(-signal.SIGKILL) == PREEMPTED
    assert classify_exit(-signal.SIGTERM) == PREEMPTED
    # a death observed during an orderly stop is never an incident
    assert classify_exit(1, stopping=True) == SHUTDOWN


def test_restart_policy_backoff_and_budget():
    policy = RestartPolicy(max_restarts=3, backoff_base_s=0.1,
                           backoff_factor=2.0, backoff_max_s=0.5)
    assert [policy.backoff(i) for i in range(4)] == [0.1, 0.2, 0.4, 0.5]
    assert policy.should_restart(CRASH, 2)
    assert not policy.should_restart(CRASH, 3)       # budget exhausted
    assert not policy.should_restart(SHUTDOWN, 0)    # clean exits stay down
    crash_only = RestartPolicy(restart_on=(CRASH,))
    assert not crash_only.should_restart(PREEMPTED, 0)


def test_restart_policy_validation():
    with pytest.raises(ValueError):
        RestartPolicy(max_restarts=-1)
    with pytest.raises(ValueError):
        RestartPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RestartPolicy(restart_on=("sigsegv",))


# ======================================================== chaos schedules
def test_chaos_policy_schedules_targets_only():
    policy = ChaosPolicy(kill_after_steps=10, kill_targets=("actor/0",),
                         kill_jitter_steps=5, seed=3)
    sched = policy.schedule_for("actor/0")
    assert sched is not None
    assert 10 <= sched.kill_step <= 15
    # deterministic: the same (seed, node) always jitters identically
    assert policy.schedule_for("actor/0").kill_step == sched.kill_step
    assert policy.schedule_for("actor/1") is None
    assert ChaosPolicy().schedule_for("actor/0") is None


def test_chaos_policy_validation():
    with pytest.raises(ValueError):
        ChaosPolicy(kill_after_steps=0)
    with pytest.raises(ValueError):
        ChaosPolicy(rpc_drop_rate=1.0)
    with pytest.raises(ValueError):
        ChaosPolicy(kill_exit_code=0)


def test_kill_schedule_disarms_after_max_kills(monkeypatch):
    from repro.resilience.chaos import RESTARTS_ENV

    class _Actor:
        def observe(self):
            return "ok"

    sched = KillSchedule("actor/0", kill_step=100, exit_code=42, max_kills=1)
    monkeypatch.setenv(RESTARTS_ENV, "0")
    assert sched.armed
    wrapped = KillSchedule("actor/0", 100, 42, 1).wrap(_Actor())
    assert wrapped.observe() == "ok"      # counts but far from kill_step
    monkeypatch.setenv(RESTARTS_ENV, "1")
    assert not sched.armed
    # a disarmed schedule returns the bare actor — no kill machinery left
    bare = _Actor()
    assert KillSchedule("actor/0", 100, 42, 1).wrap(bare) is bare


def test_rpc_injector_counts_faults():
    inj = RPCChaosInjector(drop_rate=0.9, seed=0)
    drops = 0
    for _ in range(30):
        try:
            inj.before_send()
        except ConnectionError:
            drops += 1
    assert drops == inj.injected["drops"] > 20


# ================================================== courier chaos + retry
class _Stats:
    def size(self):
        return 123


def test_courier_retries_through_injected_drops():
    """Idempotent RPCs ride through injected connection drops: the client
    retries (3 attempts) and every call still succeeds.  Seed 0 at rate
    0.3 never drops three times in a row within this window (10 drops in
    40 calls), so the test is deterministic."""
    from repro.distributed import courier

    server, handle = courier.serve(_Stats(), interface=("size",),
                                   name="stats")
    inj = RPCChaosInjector(drop_rate=0.3, seed=0)
    courier.set_rpc_chaos(inj)
    try:
        for _ in range(40):
            assert handle.size() == 123
        assert inj.injected["drops"] >= 5
    finally:
        courier.set_rpc_chaos(None)
        server.stop()


# ==================================================== elastic supervision
class _Reports:
    """Service the workers report lives into."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def put(self, value):
        with self._lock:
            self._items.append(value)

    def items(self):
        with self._lock:
            return list(self._items)


class _CrashOnce:
    """Worker: first life crashes hard; the respawn reports and exits."""

    def __init__(self, reports, exit_code=42):
        from repro.resilience.chaos import worker_restarts
        self.reports = reports
        self.exit_code = exit_code
        self.restarts = worker_restarts()

    def run(self):
        if self.restarts == 0:
            os._exit(self.exit_code)
        self.reports.put(f"alive after {self.restarts} restart")

    def stop(self):
        pass


class _PreemptOnce(_CrashOnce):
    """First life dies by signal (preemption); the respawn reports."""

    def run(self):
        if self.restarts == 0:
            os.kill(os.getpid(), signal.SIGKILL)
        self.reports.put("survived preemption")


class _AlwaysCrash:
    def __init__(self):
        pass

    def run(self):
        os._exit(7)

    def stop(self):
        pass


def _elastic_program(worker_cls, policy, **worker_kwargs):
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("elastic")
    program.restart_policy = policy
    reports = program.add_node("reports", _Reports, role="service",
                               interface=("put", "items"))
    program.add_node("worker", worker_cls, reports, role="worker",
                     **worker_kwargs)
    return program, MultiprocessLauncher(program)


def _wait_for(predicate, timeout=JOIN_S):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(0.1)
    return False


def test_supervisor_respawns_crashed_worker():
    program, launcher = _elastic_program(_CrashOnce,
                                         RestartPolicy(max_restarts=2))
    launcher.launch()
    try:
        assert _wait_for(
            lambda: program.resolve("reports").items()), \
            f"respawned worker never reported; {launcher.restart_stats()}"
    finally:
        launcher.stop()
        launcher.join(timeout=JOIN_S)
    stats = launcher.restart_stats()
    assert stats["restarts"] == {"worker": 1}
    assert stats["exit_kinds"]["worker"][0] == CRASH
    assert program.resolve("reports").items() == ["alive after 1 restart"]


def test_supervisor_respawns_preempted_worker():
    program, launcher = _elastic_program(_PreemptOnce,
                                         RestartPolicy(max_restarts=2))
    launcher.launch()
    try:
        assert _wait_for(lambda: program.resolve("reports").items())
    finally:
        launcher.stop()
        launcher.join(timeout=JOIN_S)
    stats = launcher.restart_stats()
    assert stats["exit_kinds"]["worker"][0] == PREEMPTED
    assert program.resolve("reports").items() == ["survived preemption"]


def test_supervisor_fails_fast_when_budget_exhausted():
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("exhausted")
    program.restart_policy = RestartPolicy(max_restarts=1,
                                           backoff_base_s=0.05)
    program.add_node("worker", _AlwaysCrash, role="worker")
    launcher = MultiprocessLauncher(program).launch()
    with pytest.raises(Exception, match="crash"):
        launcher.join(timeout=JOIN_S)
    # one respawn granted, the second death exhausted the budget
    assert launcher.restart_stats()["restarts"] == {"worker": 1}


def test_no_policy_means_fail_fast():
    from repro.distributed.launchers import MultiprocessLauncher
    from repro.distributed.program import Program

    program = Program("failfast")
    program.add_node("worker", _AlwaysCrash, role="worker")
    launcher = MultiprocessLauncher(program).launch()
    with pytest.raises(Exception, match="crash"):
        launcher.join(timeout=JOIN_S)
    assert launcher.restart_stats()["restarts"] == {}


# ===================================================== config validation
def test_experiment_config_resume_requires_checkpoint_dir():
    from conftest import make_dqn_catch_config
    with pytest.raises(ValueError, match="checkpoint_dir"):
        make_dqn_catch_config(resume=True)


def test_experiment_config_rejects_wrong_resilience_types():
    from conftest import make_dqn_catch_config
    with pytest.raises(ValueError, match="RestartPolicy"):
        make_dqn_catch_config(restart_policy="aggressive")
    with pytest.raises(ValueError, match="ChaosPolicy"):
        make_dqn_catch_config(chaos={"kill": True})


# ============================================ exact resume (single process)
def test_run_experiment_resume_is_bit_exact(tmp_path):
    """The parity pin: 4 episodes + final snapshot, resumed to 8, must be
    bit-identical (params, opt state, counters, train curve) to 8 episodes
    uninterrupted."""
    import jax
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    straight = run_experiment(make_dqn_catch_config(
        seed=3, min_replay_size=10, num_episodes=8, eval_episodes=0))

    cfg = make_dqn_catch_config(seed=3, min_replay_size=10, num_episodes=4,
                                eval_episodes=0,
                                checkpoint_dir=str(tmp_path))
    run_experiment(cfg)
    resumed = run_experiment(dataclasses.replace(cfg, num_episodes=8,
                                                 resume=True))

    assert resumed.learner_steps == straight.learner_steps
    assert resumed.train_returns == straight.train_returns
    assert resumed.actor_steps == straight.actor_steps
    assert resumed.counts == straight.counts
    for a, b in zip(jax.tree_util.tree_leaves(straight.learner.state),
                    jax.tree_util.tree_leaves(resumed.learner.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_experiment_resume_after_sigkill_is_bit_exact(tmp_path):
    """Kill-and-restart parity: a run hard-killed mid-training (os._exit
    from inside the train loop — no cleanup, no final save) resumes from
    its last cadence checkpoint to a state bit-identical to a run that was
    never interrupted."""
    import jax
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    src_dir = os.path.join(os.path.dirname(tests_dir), "src")
    ckpt_dir = tmp_path / "ckpt"
    script = tmp_path / "phase1.py"
    script.write_text(
        "import os, sys\n"
        f"sys.path.insert(0, {src_dir!r})\n"
        f"sys.path.insert(0, {tests_dir!r})\n"
        "from conftest import make_dqn_catch_config\n"
        "from repro.experiments import run_experiment\n"
        "class KillAfter:\n"
        "    def __init__(self, n): self.n = n\n"
        "    def __call__(self, label):\n"
        "        def log(result):\n"
        "            if label != 'train': return\n"
        "            self.n -= 1\n"
        "            if self.n <= 0: os._exit(9)\n"
        "        return log\n"
        "cfg = make_dqn_catch_config(\n"
        "    seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0,\n"
        f"    checkpoint_dir={str(ckpt_dir)!r}, checkpoint_every=1,\n"
        "    logger_factory=KillAfter(6))\n"
        "run_experiment(cfg)\n"
        "raise SystemExit('unreachable: the kill never fired')\n")
    proc = subprocess.run([sys.executable, str(script)], timeout=300,
                          capture_output=True, text=True)
    assert proc.returncode == 9, proc.stderr
    assert (ckpt_dir / "run_latest.json").exists()

    resumed = run_experiment(make_dqn_catch_config(
        seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0,
        checkpoint_dir=str(ckpt_dir), checkpoint_every=1, resume=True))
    straight = run_experiment(make_dqn_catch_config(
        seed=7, min_replay_size=10, num_episodes=10, eval_episodes=0))

    assert resumed.learner_steps == straight.learner_steps
    assert resumed.train_returns == straight.train_returns
    assert resumed.counts == straight.counts
    for a, b in zip(jax.tree_util.tree_leaves(straight.learner.state),
                    jax.tree_util.tree_leaves(resumed.learner.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_experiment_final_save_dedupes_against_cadence(tmp_path,
                                                          monkeypatch):
    """Satellite: with a per-episode cadence the final checkpoint is the
    cadence checkpoint — run_experiment must not write it twice."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment
    from repro.resilience import run_checkpoint

    saves = []
    original = run_checkpoint.RunCheckpointer.save

    def counting_save(self, step, learner_state, **kwargs):
        saves.append(int(step))
        return original(self, step, learner_state, **kwargs)

    monkeypatch.setattr(run_checkpoint.RunCheckpointer, "save",
                        counting_save)
    result = run_experiment(make_dqn_catch_config(
        seed=0, min_replay_size=10, num_episodes=6, eval_episodes=0,
        checkpoint_dir=str(tmp_path), checkpoint_every=1))
    assert saves, "cadence checkpoints never fired"
    # the last cadence save captured the final step; no duplicate final save
    assert saves[-1] == result.learner_steps
    assert len(saves) == len(set(saves))

    # cadence off -> exactly one (final) save
    saves.clear()
    run_experiment(make_dqn_catch_config(
        seed=0, min_replay_size=10, num_episodes=3, eval_episodes=0,
        checkpoint_dir=str(tmp_path / "b")))
    assert len(saves) == 1


# ================================================ distributed resume/chaos
def test_run_distributed_experiment_resumes_counts(tmp_path):
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    cfg = make_dqn_catch_config(seed=0, min_replay_size=20, eval_episodes=0,
                                checkpoint_dir=str(tmp_path))
    first = run_distributed_experiment(cfg, num_actors=2,
                                       max_actor_steps=300, timeout_s=90)
    assert (tmp_path / "run_latest.json").exists()
    first_steps = int(first.counts["actor_steps"])

    # Doctor the snapshot with a sentinel count: seeing it in the resumed
    # result proves the restore path ran end-to-end (snapshot -> restore
    # callback -> counter), without racing the actors' fresh progress.
    ck = RunCheckpointer(str(tmp_path))
    snap = ck.restore(first.learner.state)
    counts = dict(snap.counts)
    counts["resume_sentinel"] = 123.0
    ck.save(snap.step, snap.learner_state, replay=snap.replay, counts=counts)

    resumed = run_distributed_experiment(
        dataclasses.replace(cfg, resume=True), num_actors=2,
        max_actor_steps=first_steps + 50, timeout_s=90)
    assert resumed.counts.get("resume_sentinel") == 123.0
    assert resumed.counts["actor_steps"] >= first_steps + 50
    # learner state restored: its step counter continues, never resets
    assert resumed.learner_steps >= first.learner_steps


@pytest.mark.slow
def test_chaos_acceptance_kill_actor_still_learns():
    """Acceptance: a seeded chaos kill takes down an actor mid-training on
    DQN-on-Catch (multiprocess); the supervisor classifies the crash,
    respawns the replica (which disarms), and the run still reaches the
    learning threshold."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher="multiprocess",
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=400, kill_targets=("actor/0",),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000, timeout_s=240)
    assert result.counts.get("actor_steps", 0) >= 4000
    resilience = result.extras["resilience"]
    assert resilience["restarts"].get("actor/0") == 1, resilience
    assert CRASH in resilience["exit_kinds"]["actor/0"]
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6
