"""Builder-conformance net for the AgentBuilder protocol + experiments API.

Every registered ``AgentBuilder`` subclass is instantiated against a tiny
env spec and driven through the full factory contract:
replay -> adder -> dataset -> learner -> policy -> actor, ending in a real
learner step.  Plus: ``BuilderOptions`` validation, the no-duck-typing
guarantee, and single-process vs distributed parity through the SAME
builder via ``repro.experiments``.
"""
import dataclasses

import numpy as np
import pytest

import repro.agents  # noqa: F401  (imports register all builders)
import repro.policies  # noqa: F401  (registers TransformerPolicyBuilder)
from repro.builders import AgentBuilder, BuilderOptions, registered_builders
from repro.core import EnvironmentLoop, VariableClient, make_environment_spec
from repro.envs import Catch, DeepSea, PendulumSwingup


def _catch_spec():
    return make_environment_spec(Catch(seed=0))


def _collect_catch_transitions(n_episodes=10):
    from repro.adders import NStepTransitionAdder
    from repro.replay import MinSize, Table, Uniform

    env = Catch(seed=0)
    table = Table("tmp", 10_000, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(table, 1, 0.99)
    rng = np.random.RandomState(0)
    for _ in range(n_episodes):
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            a = int(rng.randint(3))
            ts = env.step(a)
            adder.add(a, ts)
    return [table._items[k].data for k in table._order]


def _make_dqn():
    from repro.agents.dqn import DQNBuilder, DQNConfig
    cfg = DQNConfig(min_replay_size=8, samples_per_insert=0.0, batch_size=8,
                    n_step=1)
    return DQNBuilder(_catch_spec(), cfg, seed=0), Catch(seed=0)


def _make_dqfd():
    from repro.agents.dqfd import (DQfDBuilder, DQfDConfig,
                                   generate_deep_sea_demos)
    demos = generate_deep_sea_demos(DeepSea(size=4, seed=0), num_demos=4)
    cfg = DQfDConfig(min_replay_size=8, samples_per_insert=0.0, batch_size=8,
                     n_step=1, demo_ratio=0.5)
    spec = make_environment_spec(DeepSea(size=4, seed=0))
    return DQfDBuilder(spec, demos, cfg, seed=0), DeepSea(size=4, seed=0)


def _make_r2d2():
    from repro.agents.r2d2 import R2D2Builder, R2D2Config
    cfg = R2D2Config(sequence_length=4, period=2, burn_in=0, batch_size=4,
                     min_replay_size=4, samples_per_insert=0.0)
    return R2D2Builder(_catch_spec(), cfg, seed=0), Catch(seed=0)


def _make_r2d3():
    from repro.agents.dqfd import generate_sequence_demos
    from repro.agents.r2d3 import R2D3Builder, R2D3Config
    env = DeepSea(size=4, seed=0)
    demos = generate_sequence_demos(DeepSea(size=4, seed=0),
                                    lambda e: e.optimal_action(),
                                    num_demos=4, sequence_length=4, period=3)
    cfg = R2D3Config(sequence_length=4, period=3, burn_in=0, batch_size=4,
                     min_replay_size=4, samples_per_insert=0.0,
                     demo_ratio=0.5)
    spec = make_environment_spec(env)
    return R2D3Builder(spec, demos, cfg, seed=0), DeepSea(size=4, seed=0)


def _make_impala():
    from repro.agents.impala import IMPALABuilder, IMPALAConfig
    cfg = IMPALAConfig(sequence_length=3, batch_size=2)
    return IMPALABuilder(_catch_spec(), cfg, seed=0), Catch(seed=0)


def _make_mcts():
    from repro.agents.mcts import MCTSBuilder, MCTSConfig
    cfg = MCTSConfig(num_simulations=4, search_depth=4, batch_size=2,
                     min_replay_size=2)
    return (MCTSBuilder(_catch_spec(), lambda seed: Catch(seed=seed), cfg,
                        seed=0), Catch(seed=0))


def _make_continuous():
    from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
    cfg = ContinuousConfig(algo="d4pg", hidden=32, batch_size=8,
                           min_replay_size=8, samples_per_insert=0.0,
                           n_step=1, num_atoms=11, vmax=30.0)
    env = PendulumSwingup(seed=0, episode_len=30)
    return (ContinuousBuilder(make_environment_spec(env), cfg, seed=0),
            PendulumSwingup(seed=0, episode_len=30))


def _make_bc():
    from repro.agents.bc import BCBuilder, BCConfig
    items = _collect_catch_transitions(4)
    return (BCBuilder(_catch_spec(), items, BCConfig(batch_size=8), seed=0),
            Catch(seed=0))


def _make_transformer_policy():
    from repro.policies import (TransformerPolicyBuilder,
                                TransformerPolicyConfig)
    cfg = TransformerPolicyConfig(num_layers=1, d_model=32, num_heads=2,
                                  num_kv_heads=1, head_dim=16, d_ff=64,
                                  window=4, sequence_length=4, period=2,
                                  batch_size=4, min_replay_size=4,
                                  samples_per_insert=0.0, backend="jnp")
    return TransformerPolicyBuilder(_catch_spec(), cfg, seed=0), Catch(seed=0)


FACTORIES = {
    "DQNBuilder": _make_dqn,
    "DQfDBuilder": _make_dqfd,
    "R2D2Builder": _make_r2d2,
    "R2D3Builder": _make_r2d3,
    "IMPALABuilder": _make_impala,
    "MCTSBuilder": _make_mcts,
    "ContinuousBuilder": _make_continuous,
    "BCBuilder": _make_bc,
    "TransformerPolicyBuilder": _make_transformer_policy,
}


def test_all_eight_builders_registered():
    names = {cls.__name__ for cls in registered_builders()}
    assert names >= set(FACTORIES), f"missing builders: {set(FACTORIES) - names}"


@pytest.mark.parametrize("cls", registered_builders(),
                         ids=lambda c: c.__name__)
def test_builder_conformance(cls):
    factory = FACTORIES.get(cls.__name__)
    assert factory is not None, (
        f"{cls.__name__} is registered but has no conformance factory — "
        f"add one to FACTORIES in tests/test_builders_api.py")
    builder, env = factory()

    # --- the typed contract
    assert isinstance(builder, AgentBuilder)
    assert isinstance(builder.options, BuilderOptions)
    assert builder.options.batch_size >= 1

    # --- factory round-trip: replay -> adder -> dataset -> learner ->
    # policy -> actor
    table = builder.make_replay()
    adder = builder.make_adder(table)
    if builder.options.offline:
        assert adder is None, "offline builders must not build adders"
    iterator = builder.make_dataset(table)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    policy = builder.make_policy(evaluation=False)
    actor = builder.make_actor(policy, VariableClient(learner), adder,
                               seed=0)
    for method in ("select_action", "observe_first", "observe", "update"):
        assert callable(getattr(actor, method)), f"actor lacks {method}"

    # --- the actor acts and (online builders) feeds the replay table
    for _ in range(3):
        ts = env.reset()
        actor.observe_first(ts)
        while not ts.last():
            action = actor.select_action(ts.observation)
            ts = env.step(action)
            actor.observe(action, ts)
    if not builder.options.offline:
        assert table.size() > 0, "actor experience never reached replay"

    # --- the learner consumes a real batch
    if not table.rate_limiter.would_block_sample() \
            and table.size() >= builder.options.batch_size:
        metrics = learner.step()
        assert np.isfinite(metrics["loss"])


def test_builder_options_validation():
    with pytest.raises(ValueError):
        BuilderOptions(batch_size=0)
    with pytest.raises(ValueError):
        BuilderOptions(variable_update_period=0)
    with pytest.raises(ValueError):
        BuilderOptions(min_observations=-1)
    with pytest.raises(ValueError):
        BuilderOptions(observations_per_step=0.0)


def test_builder_options_frozen():
    opts = BuilderOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.batch_size = 2


def test_builder_requires_options():
    class Bad(AgentBuilder):
        def make_replay(self): ...
        def make_adder(self, table): ...
        def make_dataset(self, table): ...
        def make_learner(self, iterator, priority_update_cb=None): ...
        def make_policy(self, evaluation=False): ...
        def make_actor(self, policy, variable_client, adder, seed=0): ...

    try:
        with pytest.raises(TypeError):
            Bad(options={"batch_size": 4})
    finally:
        # don't leak the test-local class into the registry
        AgentBuilder._registry.remove(Bad)


def test_single_vs_distributed_parity():
    """Acceptance: run_distributed_experiment drives the same DQN builder
    unchanged — both execution modes learn from one ExperimentConfig."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment, run_distributed_experiment

    config = make_dqn_catch_config(seed=0, min_replay_size=30,
                                   num_episodes=40, eval_episodes=10)

    single = run_experiment(config)
    assert single.counts["actor_steps"] > 0
    assert single.learner_steps > 0
    assert type(single.builder).__name__ == "DQNBuilder"

    dist = run_distributed_experiment(config, num_actors=2,
                                      max_actor_steps=800, timeout_s=90)
    assert dist.counts["actor_steps"] > 0
    assert dist.learner_steps > 0
    # parity: one config, one builder class, both execution modes evaluate
    assert type(dist.builder) is type(single.builder)
    assert dist.extras["num_actors"] == 2
    assert np.isfinite(dist.final_eval_return)
    assert np.isfinite(single.final_eval_return)


def test_offline_experiment_runs_bc():
    from repro.agents.bc import BCBuilder, BCConfig
    from repro.experiments import ExperimentConfig, run_offline_experiment

    items = _collect_catch_transitions(6)
    config = ExperimentConfig(
        builder_factory=lambda spec: BCBuilder(spec, items,
                                               BCConfig(batch_size=16),
                                               seed=0),
        environment_factory=lambda s: Catch(seed=s),
        seed=0, eval_episodes=2)
    result = run_offline_experiment(config, num_learner_steps=20)
    assert result.learner_steps == 20
    assert result.extras["dataset_size"] == len(items)
    assert np.isfinite(result.final_eval_return)


def test_offline_experiment_rejects_online_builder():
    from repro.agents.dqn import DQNBuilder
    from repro.experiments import ExperimentConfig, run_offline_experiment

    config = ExperimentConfig(
        builder_factory=lambda spec: DQNBuilder(spec, seed=0),
        environment_factory=lambda s: Catch(seed=s))
    with pytest.raises(ValueError, match="offline"):
        run_offline_experiment(config, num_learner_steps=1)


def test_worker_errors_aggregated():
    """LocalLauncher.join must surface EVERY worker failure, not just the
    first (satellite bugfix)."""
    from repro.distributed.program import (LocalLauncher, Program,
                                           WorkerErrors)

    class Exploder:
        def __init__(self, msg):
            self.msg = msg

        def run(self):
            raise RuntimeError(self.msg)

    prog = Program()
    prog.add_node("a", Exploder, "boom-a", is_worker=True)
    prog.add_node("b", Exploder, "boom-b", is_worker=True)
    launcher = LocalLauncher(prog).launch()
    with pytest.raises(WorkerErrors) as exc_info:
        launcher.join(timeout=5)
    assert len(exc_info.value.errors) == 2
    assert "boom-a" in str(exc_info.value) and "boom-b" in str(exc_info.value)


def test_handle_dunder_lookup_does_not_construct_node():
    """Dunder probes on a Handle (deepcopy/pickle/inspect) must raise
    AttributeError instead of lazily constructing the node."""
    from repro.distributed.program import Program

    constructed = []

    def factory():
        constructed.append(1)
        return object()

    prog = Program()
    handle = prog.add_node("lazy", factory)
    for dunder in ("__deepcopy__", "__copy__", "__fspath__"):
        with pytest.raises(AttributeError):
            getattr(handle, dunder)
    assert not constructed, "dunder probe constructed the node"
    # non-dunder access still resolves lazily
    assert isinstance(handle.__class__, type)   # type lookup, not __getattr__
    handle.dereference()
    assert constructed