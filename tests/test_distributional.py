"""C51 categorical machinery (D4PG/DMPO critics)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.networks.heads import l2_project


def test_l2_project_identity():
    z = jnp.linspace(0, 10, 11)
    p = jnp.zeros(11).at[3].set(1.0)
    out = l2_project(z, p, z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(p), atol=1e-6)


def test_l2_project_splits_mass_between_neighbours():
    z_q = jnp.linspace(0.0, 10.0, 11)          # spacing 1
    z_p = jnp.array([2.5])
    p = jnp.array([1.0])
    out = np.asarray(l2_project(z_p, p, z_q))
    assert out[2] == pytest.approx(0.5)
    assert out[3] == pytest.approx(0.5)


@settings(max_examples=50, deadline=None)
@given(
    shift=st.floats(-20, 20),
    scale=st.floats(0.1, 2.0),
)
def test_l2_project_preserves_probability_mass(shift, scale):
    z_q = jnp.linspace(-10.0, 10.0, 21)
    src = jnp.linspace(-5.0, 5.0, 11) * scale + shift
    p = jnp.ones(11) / 11.0
    out = np.asarray(l2_project(src, p, z_q))
    assert out.sum() == pytest.approx(1.0, abs=1e-5)
    assert (out >= -1e-7).all()


def test_l2_project_clips_out_of_support_mass_to_edges():
    z_q = jnp.linspace(0.0, 1.0, 5)
    out = np.asarray(l2_project(jnp.array([99.0]), jnp.array([1.0]), z_q))
    assert out[-1] == pytest.approx(1.0)
