"""repro.policies: incremental KV-cache decode parity, slot lifecycle,
serving, and (slow) learning on Catch under both launchers."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import make_environment_spec
from repro.envs import Catch
from repro.policies import (CacheSlotsExhausted, KVCachePool, PolicyEngine,
                            TransformerInferenceServer,
                            TransformerPolicyBuilder, TransformerPolicyConfig,
                            network)
from repro.policies.actors import _WindowBuffer

WINDOW = 4


def _cfg(**kw):
    base = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                head_dim=16, d_ff=64, window=WINDOW, epsilon=0.0,
                backend="jnp", sequence_length=10, period=10, batch_size=8,
                min_replay_size=10, samples_per_insert=0.0)
    base.update(kw)
    return TransformerPolicyConfig(**base)


def _builder(cfg=None, seed=0):
    spec = make_environment_spec(Catch(seed=0))
    return TransformerPolicyBuilder(spec, cfg or _cfg(), seed=seed)


def _params(builder, seed=0):
    obs_dim = int(np.prod(builder.spec.observations.shape))
    return network.init(jax.random.key(seed), builder.arch, obs_dim,
                        builder.num_actions)


def _oracle_q(params, builder, window, length):
    """Full-sequence recompute Q at the newest real frame."""
    q = network.q_sequence(params, builder.arch,
                           jnp.asarray(window).reshape(1, WINDOW, -1))[0]
    return np.asarray(q[max(length - 1, 0)])


# ===================================================================== parity
@pytest.mark.parametrize("backend", ["jnp", "ref"])
def test_incremental_decode_matches_full_recompute(backend):
    """Acceptance: prefill + incremental decode through the ring cache ==
    full-sequence recompute, including past the ring wrap (T > window)."""
    builder = _builder(_cfg(backend=backend))
    params = _params(builder)
    obs_shape = builder.spec.observations.shape
    engine = PolicyEngine(builder.arch, obs_shape, builder.num_actions,
                          num_slots=1, epsilon=0.0, backend=backend)
    buf = _WindowBuffer(WINDOW, obs_shape)
    buf.reset()
    rng = np.random.RandomState(1)
    for t in range(3 * WINDOW):           # wraps the ring twice
        buf.push(rng.rand(*obs_shape).astype(np.float32))
        window = buf.window_array()
        act = engine.select(params, ["env0"], window[None], [buf.t])[0]
        expected = int(np.argmax(_oracle_q(params, builder, window,
                                           min(t + 1, WINDOW))))
        assert act == expected, f"step {t} ({backend}): {act} != {expected}"
    stats = engine.stats()
    assert stats["prefill_batches"] == 1      # one prefill, then pure decode
    assert stats["decode_rows"] == 3 * WINDOW - 1


def test_mid_episode_reprefill_equivalence():
    """A slot rebuilt mid-episode from its window (the stale-cache path)
    continues with the same actions as one that decoded incrementally."""
    builder = _builder()
    params = _params(builder)
    obs_shape = builder.spec.observations.shape
    rng = np.random.RandomState(2)
    frames = [rng.rand(*obs_shape).astype(np.float32) for _ in range(10)]

    def run(reprefill_at):
        engine = PolicyEngine(builder.arch, obs_shape, builder.num_actions,
                              num_slots=1, epsilon=0.0, backend="jnp")
        buf = _WindowBuffer(WINDOW, obs_shape)
        buf.reset()
        acts = []
        for t, f in enumerate(frames):
            buf.push(f)
            if t == reprefill_at:
                engine.pool.invalidate_all()   # forces the prefill path
            acts.append(engine.select(params, ["env0"],
                                      buf.window_array()[None], [buf.t])[0])
        return acts

    assert run(reprefill_at=None) == run(reprefill_at=6)


def test_batched_rows_mix_prefill_and_decode():
    """One select() call can carry fresh episodes (prefill) and continuing
    ones (decode); every row must match its own oracle."""
    builder = _builder()
    params = _params(builder)
    obs_shape = builder.spec.observations.shape
    engine = PolicyEngine(builder.arch, obs_shape, builder.num_actions,
                          num_slots=3, epsilon=0.0, backend="jnp")
    rng = np.random.RandomState(3)
    bufs = [_WindowBuffer(WINDOW, obs_shape) for _ in range(3)]
    for b in bufs:
        b.reset()
    for t in range(8):
        if t == 5:
            bufs[1].reset()        # env1 starts a new episode mid-batch
        windows, positions = [], []
        for b in bufs:
            b.push(rng.rand(*obs_shape).astype(np.float32))
            windows.append(b.window_array())
            positions.append(b.t)
        acts = engine.select(params, ["e0", "e1", "e2"],
                             np.stack(windows), positions)
        for i, b in enumerate(bufs):
            length = min(b.t + 1, WINDOW)
            expected = int(np.argmax(_oracle_q(params, builder, windows[i],
                                               length)))
            assert acts[i] == expected, f"t={t} env{i}"


# ============================================================ slot lifecycle
def test_pool_recycle_on_episode_end():
    builder = _builder()
    pool = KVCachePool(builder.arch, num_slots=2)
    a = pool.acquire("a")
    b = pool.acquire("b")
    assert pool.held() == 2 and a.index != b.index
    pool.release("a")
    assert pool.held() == 1
    c = pool.acquire("c")               # recycles a's slot
    assert c.index == a.index
    assert c.pos == -1 and c.cache_pos == -1


def test_pool_exhaustion_backpressure():
    builder = _builder()
    pool = KVCachePool(builder.arch, num_slots=1, timeout_s=0.05)
    pool.acquire("a")
    t0 = time.monotonic()
    with pytest.raises(CacheSlotsExhausted):
        pool.acquire("b")
    assert time.monotonic() - t0 >= 0.04   # it actually waited


def test_pool_reaps_idle_slots_of_dead_clients():
    """Churn tolerance (repro.resilience): a worker that dies without
    releasing leaks its slots only until pool pressure triggers the idle
    reaper — a live episode touches its slot every step and is spared."""
    builder = _builder()
    pool = KVCachePool(builder.arch, num_slots=2, timeout_s=0.05,
                       reap_idle_s=0.1)
    dead = pool.acquire("dead-client")
    pool.acquire("live-client")
    time.sleep(0.15)                       # both slots now look idle...
    pool.lookup("live-client")             # ...but the live one is touched
    fresh = pool.acquire("fresh-client")   # pressure: reaps only the dead
    assert fresh.index == dead.index
    assert pool.stats["reaped"] == 1
    assert pool.lookup("dead-client") is None
    assert pool.lookup("live-client") is not None
    assert pool.held() == 2


def test_pool_reaping_disabled_keeps_backpressure():
    builder = _builder()
    pool = KVCachePool(builder.arch, num_slots=1, timeout_s=0.05,
                       reap_idle_s=None)
    pool.acquire("a")
    time.sleep(0.15)
    with pytest.raises(CacheSlotsExhausted):
        pool.acquire("b")
    assert pool.stats["reaped"] == 0
    assert pool.stats["exhausted_waits"] == 1

    # a blocked acquire unblocks as soon as a slot frees
    got = {}

    def late_release():
        time.sleep(0.05)
        pool.release("a")

    thread = threading.Thread(target=late_release)
    thread.start()
    got["slot"] = pool.acquire("b", timeout=2.0)
    thread.join()
    assert got["slot"].key == "b"


def test_pool_invalidate_all_marks_slots_stale():
    builder = _builder()
    pool = KVCachePool(builder.arch, num_slots=2)
    slot = pool.acquire("a")
    slot.pos = 5
    generation = pool.generation
    pool.invalidate_all()
    assert pool.generation == generation + 1
    assert slot.generation == generation      # now stale
    assert pool.held() == 1                   # still held, must re-prefill


def test_engine_weight_refresh_invalidates_cache():
    """New params object identity => every live slot re-prefills (stale-
    cache rejection after an InferenceServer weight refresh)."""
    builder = _builder()
    obs_shape = builder.spec.observations.shape
    engine = PolicyEngine(builder.arch, obs_shape, builder.num_actions,
                          num_slots=1, epsilon=0.0, backend="jnp")
    params1 = _params(builder, seed=0)
    params2 = jax.tree.map(lambda x: x, params1)    # same values, new object
    buf = _WindowBuffer(WINDOW, obs_shape)
    buf.reset()
    rng = np.random.RandomState(4)
    for t in range(3):
        buf.push(rng.rand(*obs_shape).astype(np.float32))
        engine.select(params1, ["env0"], buf.window_array()[None], [buf.t])
    assert engine.stats()["prefill_batches"] == 1
    buf.push(rng.rand(*obs_shape).astype(np.float32))
    act = engine.select(params2, ["env0"], buf.window_array()[None],
                        [buf.t])[0]
    stats = engine.stats()
    assert stats["cache_invalidations"] == 1
    assert stats["prefill_batches"] == 2      # the refresh forced a prefill
    # identical weights => the re-prefilled answer matches the oracle
    expected = int(np.argmax(_oracle_q(params1, builder,
                                       buf.window_array(), WINDOW)))
    assert act == expected


# ================================================================== serving
class _FakeSource:
    """get_variables handing out a fresh params OBJECT each bump()."""

    def __init__(self, params):
        self._params = params

    def bump(self):
        self._params = jax.tree.map(lambda x: x, self._params)

    def get_variables(self, names=("policy",)):
        return [self._params for _ in names]


def test_transformer_inference_server_roundtrip():
    builder = _builder()
    policy = builder.make_policy(evaluation=True)
    engine = policy.make_engine(num_slots=4)
    source = _FakeSource(_params(builder))
    server = TransformerInferenceServer(engine, source, max_batch_size=4,
                                        max_wait_ms=1.0, update_period=1)
    try:
        assert server.window() == WINDOW
        obs_shape = builder.spec.observations.shape
        rng = np.random.RandomState(5)
        bufs = [_WindowBuffer(WINDOW, obs_shape) for _ in range(2)]
        for b in bufs:
            b.reset()
        for t in range(WINDOW + 2):
            for b in bufs:
                b.push(rng.rand(*obs_shape).astype(np.float32))
            windows = np.stack([b.window_array() for b in bufs])
            positions = np.asarray([b.t for b in bufs])
            actions = server.select_action(windows, positions, "client-1")
            assert actions.shape == (2,)
        stats = server.stats()
        assert stats["requests"] == WINDOW + 2
        assert stats["rows"] == 2 * (WINDOW + 2)
        assert stats["pool_held_slots"] == 2

        # weight refresh (update_period=1: every batch refetches; bump makes
        # the fetch return a NEW object) => cache invalidation + re-prefill
        source.bump()
        for b in bufs:
            b.push(rng.rand(*obs_shape).astype(np.float32))
        windows = np.stack([b.window_array() for b in bufs])
        positions = np.asarray([b.t for b in bufs])
        server.select_action(windows, positions, "client-1")
        assert server.stats()["cache_invalidations"] >= 1

        # release frees the client's slots
        server.release("client-1")
        assert server.stats()["pool_held_slots"] == 0
    finally:
        server.stop()


def test_server_rejects_new_requests_after_stop():
    from repro.distributed.courier import CourierClosed
    builder = _builder()
    policy = builder.make_policy(evaluation=True)
    server = TransformerInferenceServer(policy.make_engine(num_slots=2),
                                        _FakeSource(_params(builder)),
                                        max_batch_size=2)
    server.stop()
    with pytest.raises(CourierClosed):
        server.select_action(np.zeros((1, WINDOW, 10, 5), np.float32),
                             np.zeros((1,), np.int64), "c")


# ============================================================ learning (slow)
@pytest.mark.slow
def test_transformer_policy_learns_catch():
    """Acceptance: TransformerPolicyBuilder trains DQN-style on Catch
    through run_experiment (single process, local KV-cache decode)."""
    from conftest import make_transformer_catch_config
    from repro.experiments import run_experiment

    config = make_transformer_catch_config(seed=0, num_episodes=250,
                                           eval_every=0, eval_episodes=20)
    result = run_experiment(config)
    assert result.learner_steps > 0
    early = np.mean(result.train_returns[:30])
    final = result.final_eval_return
    assert np.isfinite(final)
    assert final > early, (f"no improvement: eval {final:.2f} vs "
                           f"early-train {early:.2f}")


@pytest.mark.slow
def test_transformer_policy_server_inference_local_launcher():
    """Acceptance: inference='server' on the local launcher — actors RPC the
    TransformerInferenceServer, which runs continuous-batching KV decode."""
    from conftest import make_transformer_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_transformer_catch_config(
        seed=0, launcher="local", inference="server", num_envs_per_actor=2)
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=400, timeout_s=120)
    assert result.counts["actor_steps"] > 0
    assert result.learner_steps > 0
    stats = result.extras["inference"]
    assert stats["decode_rows"] > stats["prefill_rows"] > 0
    assert stats["batches"] > 0


@pytest.mark.slow
def test_transformer_policy_server_inference_multiprocess_launcher():
    """Acceptance: the same config crosses process boundaries — windows over
    courier RPC, cache slots keyed per remote client."""
    from conftest import make_transformer_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_transformer_catch_config(
        seed=0, launcher="multiprocess", inference="server",
        num_envs_per_actor=2)
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=300, timeout_s=400)
    assert result.counts["actor_steps"] > 0
    stats = result.extras["inference"]
    assert stats["decode_rows"] > 0 and stats["prefill_rows"] > 0
