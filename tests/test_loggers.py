import csv
import os

from repro.core.loggers import CSVLogger, Dispatcher, InMemoryLogger, TerminalLogger


def test_csv_logger_roundtrip(tmp_path):
    path = str(tmp_path / "log.csv")
    lg = CSVLogger(path)
    lg({"step": 1, "return": 0.5})
    lg({"step": 2, "return": 0.7, "extra_ignored": 1})
    with open(path) as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == 2
    assert rows[1]["step"] == "2"


def test_in_memory_and_dispatch(capsys):
    mem = InMemoryLogger()
    disp = Dispatcher(mem, TerminalLogger("test"))
    disp({"a": 1.0})
    assert mem.rows == [{"a": 1.0}]
    assert "a=1.000" in capsys.readouterr().out
