import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer


def _state(x=1.0):
    return {"params": {"w": jnp.full((3, 3), x)}, "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state(2.5)
    ck.save(state, step=10, metadata={"walltime": 12.5})
    restored, meta = ck.restore(_state(0.0))
    assert meta["step"] == 10
    assert meta["walltime"] == 12.5
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.5)


def test_keep_limit_garbage_collects(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(_state(step), step=step)
    assert ck.list_steps() == [3, 4]


def test_restore_empty_returns_none(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state, meta = ck.restore(_state())
    assert state is None and meta is None


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(_state(1.0), step=1)
    ck.save(_state(2.0), step=2)
    restored, meta = ck.restore(_state(), step=1)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.0)
