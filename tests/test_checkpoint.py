import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointError
from test_builders_api import FACTORIES


def _state(x=1.0):
    return {"params": {"w": jnp.full((3, 3), x)}, "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state(2.5)
    ck.save(state, step=10, metadata={"walltime": 12.5})
    restored, meta = ck.restore(_state(0.0))
    assert meta["step"] == 10
    assert meta["walltime"] == 12.5
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.5)


def test_keep_limit_garbage_collects(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(_state(step), step=step)
    assert ck.list_steps() == [3, 4]


def test_restore_empty_returns_none(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state, meta = ck.restore(_state())
    assert state is None and meta is None


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(_state(1.0), step=1)
    ck.save(_state(2.0), step=2)
    restored, meta = ck.restore(_state(), step=1)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.0)


# ------------------------- crash-consistency manifest (repro.resilience)
def test_save_writes_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=3)
    with open(tmp_path / "checkpoint_latest.json") as f:
        manifest = json.load(f)
    assert manifest == {"step": 3, "file": "checkpoint_3.npz"}


def test_latest_step_prefers_manifest_over_newest_file(tmp_path):
    # A stray higher-numbered npz (a half-finished save from a crashed
    # writer) must not shadow the manifest's published step.
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=1)
    np.savez(str(tmp_path / "checkpoint_9.npz"), junk=np.zeros(1))
    assert ck.latest_step() == 1


def test_manifest_pointing_at_missing_file_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=2)
    os.unlink(tmp_path / "checkpoint_2.npz")
    with pytest.raises(CheckpointError, match="missing"):
        ck.latest_step()


def test_restore_leaf_count_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=1)
    with pytest.raises(CheckpointError, match="leaves"):
        ck.restore({"params": {"w": jnp.zeros((3, 3))}})   # no "step" leaf


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=1)
    bad = {"params": {"w": jnp.zeros((2, 2))}, "step": jnp.asarray(0)}
    with pytest.raises(CheckpointError, match="shape"):
        ck.restore(bad)


def test_restore_preserves_integer_dtypes(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.ones((2,), jnp.float32),
             "steps": jnp.asarray(11, jnp.int32)}
    ck.save(state, step=1)
    restored, _ = ck.restore({"w": jnp.zeros((2,)), "steps": jnp.asarray(0)})
    assert np.asarray(restored["steps"]).dtype == np.int32
    assert int(restored["steps"]) == 11


# --------------------- learner-state round-trip across EVERY builder
@pytest.mark.parametrize("name", sorted(FACTORIES))
def test_learner_state_roundtrip(tmp_path, name):
    """Exact-resume foundation: any builder's learner state — params,
    optimizer moments, integer step counters — survives a checkpoint
    round-trip bit-identically, restored into a FRESH factory's template."""
    from repro.core import VariableClient

    builder, env = FACTORIES[name]()
    table = builder.make_replay()
    adder = builder.make_adder(table)
    learner = builder.make_learner(
        builder.make_dataset(table),
        priority_update_cb=table.update_priorities)
    actor = builder.make_actor(builder.make_policy(evaluation=False),
                               VariableClient(learner), adder, seed=0)
    for _ in range(3):
        ts = env.reset()
        actor.observe_first(ts)
        while not ts.last():
            action = actor.select_action(ts.observation)
            ts = env.step(action)
            actor.observe(action, ts)
    if not table.rate_limiter.would_block_sample() \
            and table.size() >= builder.options.batch_size:
        # populate optimizer moments and advance the step counter so the
        # round-trip covers non-initial state
        learner.step()
        learner.step()

    ck = Checkpointer(str(tmp_path))
    ck.save(learner.state, step=1)

    fresh_builder, _ = FACTORIES[name]()
    fresh = fresh_builder.make_learner(
        fresh_builder.make_dataset(fresh_builder.make_replay()))
    restored, _ = ck.restore(fresh.state)
    orig = jax.tree_util.tree_leaves(learner.state)
    back = jax.tree_util.tree_leaves(restored)
    assert len(orig) == len(back)
    for a, b in zip(orig, back):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    # the setter the run-wide resume path uses accepts the restored state
    fresh.state = restored
