"""R2D3: recurrent learner with demonstration sequences."""
import numpy as np

from repro.agents.builders import make_agent
from repro.agents.dqfd import generate_sequence_demos
from repro.agents.r2d3 import R2D3Builder, R2D3Config
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import DeepSea


def test_r2d3_learns_deep_sea_with_demos():
    env = DeepSea(size=5, seed=1)
    spec = make_environment_spec(env)
    # period < length: overlapping sequences so the terminal (rewarding)
    # transition appears at a non-final index of some stored sequence (the
    # within-sequence TD loss bootstraps from t+1 and excludes the last slot).
    demos = generate_sequence_demos(
        DeepSea(size=5, seed=1), lambda e: e.optimal_action(),
        num_demos=15, sequence_length=5, period=4)
    assert demos and demos[0]["observation"].shape[0] == 5
    cfg = R2D3Config(sequence_length=5, period=4, burn_in=0, batch_size=16,
                     min_replay_size=40, samples_per_insert=0,
                     target_update_period=40, epsilon=0.1, demo_ratio=0.5)
    agent = make_agent(R2D3Builder(spec, demos, cfg, seed=3))
    loop = EnvironmentLoop(env, agent)
    rets = [loop.run_episode()["episode_return"] for _ in range(250)]
    assert int(agent.learner.state.steps) > 0
    # with 50% demo batches the treasure should be found regularly
    assert np.mean(np.asarray(rets[-50:]) > 0.5) > 0.2


def test_distributed_with_evaluator_node():
    import time
    from repro.agents.builders import make_distributed_agent
    from repro.agents.dqn import DQNBuilder, DQNConfig
    from repro.envs import Catch

    spec = make_environment_spec(Catch(seed=0))
    builder = DQNBuilder(spec, DQNConfig(min_replay_size=50,
                                         samples_per_insert=4.0,
                                         batch_size=16, n_step=1), seed=0)
    dist = make_distributed_agent(builder, lambda s: Catch(seed=s),
                                  num_actors=1, with_evaluator=True)
    try:
        deadline = time.time() + 30
        while time.time() < deadline:
            if len(dist.evaluator.returns) >= 3:
                break
            time.sleep(0.3)
        assert len(dist.evaluator.returns) >= 3
        counts = dist.counter.get_counts()
        assert counts.get("evaluator_episodes", 0) >= 3
        assert counts.get("actor_steps", 0) > 0
    finally:
        dist.stop()
