"""End-to-end behaviour tests: single-process and distributed agents learn;
the same learner runs offline (§2.6); the environment loop contract holds."""
import time

import numpy as np
import pytest

from repro.agents.builders import make_agent, make_distributed_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import Counter, EnvironmentLoop, make_environment_spec
from repro.envs import Catch


def _dqn_builder(spec, spi=0.0, seed=0):
    cfg = DQNConfig(min_replay_size=50, samples_per_insert=spi,
                    batch_size=32, n_step=1, epsilon=0.2)
    return DQNBuilder(spec, cfg, seed=seed)


def test_single_process_dqn_learns_catch():
    env = Catch(seed=1)
    spec = make_environment_spec(env)
    agent = make_agent(_dqn_builder(spec))
    loop = EnvironmentLoop(env, agent)
    rets = [loop.run_episode()["episode_return"] for _ in range(200)]
    assert np.mean(rets[-30:]) > np.mean(rets[:30]) + 0.5
    assert np.mean(rets[-30:]) > 0.2


def test_distributed_dqn_runs_and_learns():
    spec = make_environment_spec(Catch(seed=0))
    builder = _dqn_builder(spec, spi=8.0, seed=1)
    dist = make_distributed_agent(builder, lambda seed: Catch(seed=seed),
                                  num_actors=2)
    deadline = time.time() + 60
    try:
        while time.time() < deadline:
            counts = dist.counter.get_counts()
            if counts.get("actor_steps", 0) > 3000:
                break
            time.sleep(0.5)
        counts = dist.counter.get_counts()
        assert counts.get("actor_steps", 0) > 500, counts
        assert int(dist.learner.state.steps) > 10
        rl = dist.table.rate_limiter
        assert rl.samples > 0 and rl.inserts > rl.min_size_to_sample
    finally:
        dist.stop()


def test_offline_learner_from_fixed_dataset():
    """§2.6: apply the DQN learner to a fixed dataset — no actors at all."""
    import jax
    from repro.agents import dqn as dqn_lib
    from repro.adders import NStepTransitionAdder
    from repro.replay import MinSize, Table, Uniform, dataset_from_list

    env = Catch(seed=5)
    spec = make_environment_spec(env)
    table = Table("tmp", 100_000, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(table, 1, 0.99)
    # behaviour data: track-the-ball policy + 20% exploration — pure-expert
    # data has no action coverage and offline Q-learning picks unseen
    # actions greedily (the distribution-shift point of §3.7).
    rng = np.random.RandomState(5)
    for _ in range(120):
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            board = ts.observation
            ball = int(np.argmax(board[:-1].max(axis=0)))
            paddle = int(np.argmax(board[-1]))
            a = int(1 + np.sign(ball - paddle))
            if rng.rand() < 0.2:
                a = int(rng.randint(3))
            ts = env.step(a)
            adder.add(a, ts)
    items = [table._items[k].data for k in table._order]
    from repro.core import FeedForwardActor, VariableClient

    def evaluate(learner, policy, episodes=20):
        actor = FeedForwardActor(policy, VariableClient(learner))
        loop = EnvironmentLoop(Catch(seed=9), actor)
        return np.mean([loop.run_episode()["episode_return"]
                        for _ in range(episodes)])

    # BC: the offline baseline (§3.7) — should track the behaviour policy
    from repro.agents import bc as bc_lib
    bcfg = bc_lib.BCConfig()
    bl = bc_lib.make_learner(spec, bcfg, dataset_from_list(items, 64),
                             jax.random.key(1))
    for _ in range(300):
        bl.step()
    bc_ret = evaluate(bl, bc_lib.make_eval_policy(spec, bcfg))
    assert bc_ret > 0.3, bc_ret

    # offline double-DQN: runs, losses finite, loss decreases from start.
    # We deliberately do NOT gate on its greedy-eval return: as Fig 12 of
    # the paper reports for offline D4PG, value-based offline learners on
    # small datasets degrade with prolonged training (overfitting /
    # extrapolation error) — we reproduce that behaviour too.
    cfg = dqn_lib.DQNConfig(prioritized=False)
    learner = dqn_lib.make_learner(spec, cfg, dataset_from_list(items, 64),
                                   jax.random.key(0))
    losses = [learner.step()["loss"] for _ in range(400)]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-50:]) < np.mean(losses[:5])


def test_environment_loop_counts_actor_steps():
    env = Catch(seed=0)
    spec = make_environment_spec(env)
    agent = make_agent(_dqn_builder(spec))
    counter = Counter()
    loop = EnvironmentLoop(env, agent, counter=counter, label="actor")
    loop.run(num_episodes=3)
    counts = counter.get_counts()
    assert counts["actor_episodes"] == 3
    assert counts["actor_steps"] == 27          # catch episodes are 9 steps
