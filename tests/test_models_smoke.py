"""Per-arch smoke tests: a REDUCED same-family variant (2 layers,
d_model<=256, <=4 experts) runs one forward + one train step + one decode
step on CPU, asserting shapes and no NaNs.  The FULL configs are exercised
only by the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.launch.steps import init_train_state, make_serve_step, make_train_step
from repro.models import transformer
from repro.optim import adam

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng or jax.random.key(0)
    text = s - cfg.vision_tokens if cfg.arch_type == "vlm" else s
    batch = {
        "tokens": jax.random.randint(rng, (b, text), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (b, text), 0, cfg.vocab_size),
        "rewards": jnp.zeros((b, text), jnp.float32),
        "discounts": jnp.ones((b, text), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        batch["vision"] = 0.1 * jax.random.normal(
            rng, (b, cfg.vision_tokens, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            rng, (b, cfg.encoder_seq, cfg.d_model))
    return batch, text


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = reduced(ARCHS[name])
    params = transformer.init(jax.random.key(0), cfg, jnp.float32)
    batch, text = _batch(cfg)
    logits, aux = transformer.forward(params, cfg, batch, remat="none")
    assert logits.shape == (2, text, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    if cfg.arch_type == "moe":
        assert "moe_aux" in aux and float(aux["moe_aux"]) >= 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_no_nans(name):
    cfg = reduced(ARCHS[name])
    opt = adam(1e-3)
    state = init_train_state(jax.random.key(0), cfg, opt,
                             param_dtype=jnp.float32)
    step = make_train_step(cfg, opt, remat="none", microbatches=1)
    batch, _ = _batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), jax.tree.map(
            lambda a, b: a - b, new_state.params, state.params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_decode_step(name):
    cfg = reduced(ARCHS[name])
    params = transformer.init(jax.random.key(0), cfg, jnp.float32)
    cache = transformer.init_cache(cfg, 2, 32, jnp.float32)
    serve = make_serve_step(cfg)
    token = jnp.zeros((2, 1), jnp.int32)
    next_token, logits, new_cache = jax.jit(serve)(params, cache, token,
                                                   jnp.int32(0))
    assert next_token.shape == (2, 1)
    assert logits.shape == (2, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # pad-vocab entries are masked out of the argmax
    assert int(jnp.max(next_token)) < cfg.vocab_size


def test_microbatched_grads_match_full_batch():
    # SGD makes the update linear in the gradients, so the microbatched and
    # full-batch updates must agree to f32 accumulation noise (Adam's
    # rescaling would amplify tiny grad diffs to O(lr)).
    from repro.optim import sgd
    cfg = reduced(ARCHS["qwen3-1.7b"])
    opt = sgd(1.0)
    batch, _ = _batch(cfg, b=4, s=32)
    s0 = init_train_state(jax.random.key(0), cfg, opt, param_dtype=jnp.float32)
    one = jax.jit(make_train_step(cfg, opt, remat="none", microbatches=1))
    four = jax.jit(make_train_step(cfg, opt, remat="none", microbatches=4))
    s1, m1 = one(s0, batch)
    s0b = init_train_state(jax.random.key(0), cfg, opt, param_dtype=jnp.float32)
    s4, m4 = four(s0b, batch)
    # updates equal the (negated) mean grads; compare them directly
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         s1.params, s4.params)
    assert max(jax.tree.leaves(diffs)) < 2e-4
    assert abs(float(m1["ce"]) - float(m4["ce"])) < 1e-3


def test_vlm_interleaves_vision_tokens():
    cfg = reduced(ARCHS["internvl2-26b"])
    params = transformer.init(jax.random.key(0), cfg, jnp.float32)
    batch, text = _batch(cfg)
    logits, _ = transformer.forward(params, cfg, batch, remat="none")
    assert logits.shape[1] == text          # vision prefix stripped
    # changing a vision embedding must change text logits (cross-modal flow)
    batch2 = dict(batch)
    batch2["vision"] = batch["vision"] + 1.0
    logits2, _ = transformer.forward(params, cfg, batch2, remat="none")
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-4


def test_num_params_close_to_reported():
    # sanity: param-count formula within 20% of actual leaves
    for name in ("qwen3-1.7b", "mamba2-780m", "qwen2-moe-a2.7b"):
        cfg = reduced(ARCHS[name])
        params = transformer.init(jax.random.key(0), cfg, jnp.float32)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.num_params()
        assert abs(actual - est) / actual < 0.35, (name, actual, est)
