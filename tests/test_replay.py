import threading
import time

import numpy as np
import pytest

from repro.replay import (Fifo, Lifo, MinSize, Prioritized, RateLimiterTimeout,
                          SampleToInsertRatio, Table, Uniform, as_iterator)


def test_table_insert_sample_uniform():
    t = Table("t", capacity=100, selector=Uniform(0), rate_limiter=MinSize(1))
    for i in range(10):
        t.insert({"x": np.array([i])})
    assert t.size() == 10
    items = t.sample(5)
    assert len(items) == 5
    for item, prob in items:
        assert prob == pytest.approx(1 / 10)


def test_table_capacity_eviction_fifo_removal():
    t = Table("t", capacity=5, selector=Uniform(0), rate_limiter=MinSize(1))
    keys = [t.insert(i) for i in range(8)]
    assert t.size() == 5
    live = {it.data for it, _ in t.sample(50)}
    assert live <= {3, 4, 5, 6, 7}


def test_fifo_queue_semantics():
    t = Table("q", capacity=100, selector=Fifo(), rate_limiter=MinSize(1))
    for i in range(5):
        t.insert(i)
    got = [t.sample(1)[0][0].data for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]


def test_lifo_semantics():
    t = Table("q", capacity=100, selector=Lifo(), rate_limiter=MinSize(1))
    for i in range(5):
        t.insert(i)
    assert t.sample(1)[0][0].data == 4


def test_prioritized_prefers_high_priority():
    sel = Prioritized(priority_exponent=1.0, seed=0)
    t = Table("p", capacity=100, selector=sel, rate_limiter=MinSize(1))
    t.insert("low", priority=0.01)
    t.insert("high", priority=10.0)
    counts = {"low": 0, "high": 0}
    for _ in range(200):
        item, prob = t.sample(1)[0]
        counts[item.data] += 1
    assert counts["high"] > 150


def test_priority_update_changes_distribution():
    sel = Prioritized(priority_exponent=1.0, seed=0)
    t = Table("p", capacity=10, selector=sel, rate_limiter=MinSize(1))
    k1 = t.insert("a", priority=1.0)
    k2 = t.insert("b", priority=1.0)
    t.update_priorities([k1], [100.0])
    counts = {"a": 0, "b": 0}
    for _ in range(100):
        counts[t.sample(1)[0][0].data] += 1
    assert counts["a"] > 90


def test_rate_limiter_blocks_sampler_until_min_size():
    limiter = MinSize(5)
    t = Table("t", 100, Uniform(0), limiter)
    t.insert(0)
    with pytest.raises(RateLimiterTimeout):
        t.sample(1, timeout=0.1)


def test_spi_ratio_blocks_fast_learner():
    limiter = SampleToInsertRatio(samples_per_insert=2.0, min_size_to_sample=2,
                                  error_buffer=4.0)
    t = Table("t", 100, Uniform(0), limiter)
    for i in range(4):
        t.insert(i)
    # allowed samples ~ spi*(inserts - min) + tolerance = 2*2+4 = 8ish
    n = 0
    try:
        for _ in range(50):
            t.sample(1, timeout=0.05)
            n += 1
    except RateLimiterTimeout:
        pass
    assert 2 <= n <= 12


def test_spi_ratio_blocks_fast_actor():
    limiter = SampleToInsertRatio(samples_per_insert=1.0, min_size_to_sample=1,
                                  error_buffer=2.0)
    t = Table("t", 1000, Uniform(0), limiter)
    n = 0
    try:
        for i in range(100):
            t.insert(i, timeout=0.05)
            n += 1
    except RateLimiterTimeout:
        pass
    # inserts must stall once the learner lags by > error buffer
    assert n < 100


def test_spi_concurrent_ratio_holds():
    spi, minsize, tol = 4.0, 10, 20.0
    limiter = SampleToInsertRatio(spi, minsize, tol)
    t = Table("t", 10_000, Uniform(0), limiter)
    stop = time.time() + 1.5

    def actor():
        while time.time() < stop:
            try:
                t.insert(np.zeros(2), timeout=0.2)
            except RateLimiterTimeout:
                pass

    def learner():
        while time.time() < stop:
            try:
                t.sample(1, timeout=0.2)
            except RateLimiterTimeout:
                pass

    threads = [threading.Thread(target=actor) for _ in range(2)] + \
              [threading.Thread(target=learner) for _ in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    ins, samp = limiter.inserts, limiter.samples
    assert ins > minsize
    # |samples - spi*(inserts-minsize)| bounded by tolerance + in-flight slack
    assert abs(samp - spi * (ins - minsize)) <= tol + spi * 8


def test_dataset_iterator_batches():
    t = Table("t", 100, Uniform(0), MinSize(1))
    for i in range(10):
        t.insert({"obs": np.full((3,), i, np.float32)})
    it = as_iterator(t, batch_size=4)
    sample = next(it)
    assert sample.data["obs"].shape == (4, 3)
    assert sample.info.keys.shape == (4,)
