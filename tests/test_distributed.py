"""Launcher conformance suite + courier RPC layer.

The same ``Program`` graph must behave identically on every registered
backend (``local`` threads, ``multiprocess`` OS processes): graph
resolution through handle edges, fail-fast on worker death, stop/join
idempotence, join-timeout reporting, and handle pickling degradation
(in-memory ``Handle`` -> courier ``RemoteHandle``).  Worker/service classes
here are module-level so the multiprocess backend can pickle them into
spawn children.
"""
import pickle
import threading
import time

import pytest

from repro.distributed import (JoinTimeout, Launcher, LauncherBase,
                               RemoteError, RemoteHandle, WorkerErrors,
                               get_launcher, register_launcher, serve)
from repro.distributed.program import Handle, Program, Replica

BACKENDS = ["local", "multiprocess"]

# Generous: spawn children pay interpreter startup (~1-2s each).
JOIN_S = 60


# --------------------------------------------------------------- node types
class Source:
    def __init__(self, value=41):
        self.value = value

    def get(self):
        return self.value


class Sink:
    """Service the workers report into (the parent cannot reach into a child
    process to read a worker attribute, so conformance tests observe worker
    effects through a service node)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items = []

    def put(self, value):
        with self._lock:
            self._items.append(value)

    def items(self):
        with self._lock:
            return list(self._items)


class Bridge:
    """Worker: one read from source, one write to sink, exit."""

    def __init__(self, source, sink, offset=1):
        # the key Launchpad property: source/sink may be Handles, courier
        # RemoteHandles, or the objects; this code cannot tell.
        self.source = source
        self.sink = sink
        self.offset = offset

    def run(self):
        self.sink.put(self.source.get() + self.offset)


class Exploder:
    def __init__(self, message="boom"):
        self.message = message

    def run(self):
        raise ValueError(self.message)


class Spinner:
    """Worker: loop until stopped, reporting liveness through the sink."""

    def __init__(self, sink=None):
        self.sink = sink
        self._stop = threading.Event()

    def run(self):
        while not self._stop.is_set():
            if self.sink is not None:
                self.sink.put(1)
            self._stop.wait(0.01)

    def stop(self):
        self._stop.set()


class Stubborn:
    """Worker that ignores stop requests (for join-timeout reporting)."""

    def run(self):
        time.sleep(120)


# Fake learners driving LearnerReplicaWorker through the conformance suite
# (module-level for the multiprocess backend's pickling).
class TickLearner:
    """Minimal learner: state is a float scalar; step() bumps it."""

    def __init__(self, value=0.0, step_s=0.0):
        import jax.numpy as jnp
        self.state = jnp.asarray(value, jnp.float32)
        self.step_s = step_s

    def step(self):
        import jax.numpy as jnp
        if self.step_s:
            time.sleep(self.step_s)
        self.state = self.state + jnp.asarray(1.0, jnp.float32)
        return {}

    def get_variables(self, names=()):
        return [float(self.state)]


class ExplodingLearner(TickLearner):
    def __init__(self, blow_at=3):
        super().__init__()
        self.blow_at = blow_at

    def step(self):
        if float(self.state) >= self.blow_at:
            raise ValueError("replica-boom")
        return super().step()


class SleepyLearner(TickLearner):
    """step() sleeps long enough to straggle past a short join timeout."""

    def __init__(self):
        super().__init__(step_s=8.0)


def _cleanup(launcher):
    """Best-effort teardown for tests that leave stubborn runners behind."""
    launcher.stop()
    for proc in getattr(launcher, "processes", {}).values():
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=10)


# ------------------------------------------------------- conformance suite
@pytest.mark.parametrize("backend", BACKENDS)
def test_graph_resolution_through_handles(backend):
    prog = Program()
    sink = prog.add_node("sink", Sink, role="service",
                         interface=("put", "items"))
    src = prog.add_node("source", Source, 41, role="service",
                        interface=("get",))
    prog.add_node("bridge", Bridge, src, sink, role="worker")
    launcher = get_launcher(backend)(prog).launch()
    launcher.join(timeout=JOIN_S)
    assert prog.resolve("sink").items() == [42]


@pytest.mark.parametrize("backend", BACKENDS)
def test_replicated_workers(backend):
    """num_replicas expands a worker node into a pool; Replica args give
    each member its own value."""
    prog = Program()
    sink = prog.add_node("sink", Sink, role="service",
                         interface=("put", "items"))
    src = prog.add_node("source", Source, 100, role="service",
                        interface=("get",))
    handles = prog.add_node("bridge", Bridge, src, sink,
                            Replica(lambda i: i), role="worker",
                            num_replicas=3)
    assert [h.node_name for h in handles] == ["bridge/0", "bridge/1",
                                              "bridge/2"]
    launcher = get_launcher(backend)(prog).launch()
    launcher.join(timeout=JOIN_S)
    assert sorted(prog.resolve("sink").items()) == [100, 101, 102]


@pytest.mark.parametrize("backend", BACKENDS)
def test_fail_fast_on_worker_death(backend):
    """The first worker failure stops every sibling; join surfaces it."""
    prog = Program()
    sink = prog.add_node("sink", Sink, role="service",
                         interface=("put", "items"))
    prog.add_node("spinner", Spinner, sink, role="worker")
    prog.add_node("exploder", Exploder, "boom", role="worker")
    launcher = get_launcher(backend)(prog).launch()
    with pytest.raises(Exception) as exc_info:
        launcher.join(timeout=JOIN_S)
    assert "boom" in str(exc_info.value)
    assert launcher.should_stop()
    # spinner observed the fail-fast stop and exited (no timeout needed)
    assert not isinstance(exc_info.value, (JoinTimeout, WorkerErrors)) \
        or all(not isinstance(e, JoinTimeout)
               for e in getattr(exc_info.value, "errors", []))


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_worker_failures_aggregate(backend):
    """Multiple failures arrive as one WorkerErrors — none dropped."""
    prog = Program()
    prog.add_node("a", Exploder, "boom-a", role="worker")
    prog.add_node("b", Exploder, "boom-b", role="worker")
    launcher = get_launcher(backend)(prog).launch()
    # Fail-fast may classify the second death as shutdown-noise only for
    # user stops; two genuine explosions must both surface.
    with pytest.raises(Exception) as exc_info:
        launcher.join(timeout=JOIN_S)
    err = exc_info.value
    messages = (" ".join(str(e) for e in err.errors)
                if isinstance(err, WorkerErrors) else str(err))
    assert "boom-a" in messages and "boom-b" in messages


@pytest.mark.parametrize("backend", BACKENDS)
def test_stop_join_idempotent(backend):
    prog = Program()
    sink = prog.add_node("sink", Sink, role="service",
                         interface=("put", "items"))
    prog.add_node("spinner", Spinner, sink, role="worker")
    launcher = get_launcher(backend)(prog).launch()
    deadline = time.time() + JOIN_S
    while not prog.resolve("sink").items() and time.time() < deadline:
        time.sleep(0.02)
    assert prog.resolve("sink").items(), "spinner never ran"
    launcher.stop()
    launcher.stop()
    launcher.join(timeout=JOIN_S)
    launcher.join(timeout=JOIN_S)
    assert launcher.should_stop()


@pytest.mark.parametrize("backend", BACKENDS)
def test_join_timeout_names_stragglers(backend):
    prog = Program()
    prog.add_node("stubborn", Stubborn, role="worker")
    launcher = get_launcher(backend)(prog).launch()
    time.sleep(0.3 if backend == "local" else 3.0)   # let the child boot
    launcher.stop()
    with pytest.raises(JoinTimeout) as exc_info:
        launcher.join(timeout=0.5)
    assert "stubborn" in exc_info.value.node_names
    # process backends reap the straggler instead of leaking it
    for proc in getattr(launcher, "processes", {}).values():
        assert not proc.is_alive()
    _cleanup(launcher)


@pytest.mark.parametrize("backend", BACKENDS)
def test_handle_pickling_roundtrip(backend):
    """A handle crossing a process boundary degrades to a courier
    RemoteHandle with identical call syntax — and survives re-pickling."""
    prog = Program()
    handle = prog.add_node("source", Source, 41, role="service",
                           interface=("get",))
    launcher = get_launcher(backend)(prog).launch()
    try:
        launcher.serve("source")   # idempotent (multiprocess already did)
        remote = pickle.loads(pickle.dumps(handle))
        assert isinstance(remote, RemoteHandle)
        assert remote.get() == 41
        # RemoteHandle itself round-trips (its socket never pickles)
        remote2 = pickle.loads(pickle.dumps(remote))
        assert remote2.get() == 41
        # the declared interface survives the boundary
        with pytest.raises(AttributeError):
            remote.value
    finally:
        launcher.stop()
        launcher.join(timeout=JOIN_S)


# ----------------------------------------- learner-replica node conformance
def _replica_program(learners, average_period=2, max_steps=6):
    """The multi-learner node shape ``make_distributed_agent`` emits:
    ``learner/replica_i`` run+serve hybrids around a shared
    ``learner/param_server`` rendezvous."""
    from repro.learners import (PARAM_SERVER_INTERFACE, LearnerReplicaWorker,
                                ParameterServer)
    prog = Program()
    server = ParameterServer(len(learners), average_period)
    prog.add_node("learner/param_server", lambda: server, role="service",
                  interface=PARAM_SERVER_INTERFACE)
    handles = []
    for i, learner in enumerate(learners):
        worker = LearnerReplicaWorker(learner, server, i, average_period,
                                      max_steps=max_steps)
        handles.append(prog.add_node(f"learner/replica_{i}",
                                     lambda w=worker: w, role="service",
                                     interface=("get_variables",)))
    return prog, server, handles


@pytest.mark.parametrize("backend", BACKENDS)
def test_learner_replica_nodes_step_and_average(backend):
    """Replica nodes run as run+serve hybrids on every backend: both step
    to max_steps, rendezvous at the param server, and serve exactly their
    declared interface."""
    prog, server, handles = _replica_program(
        [TickLearner(0.0), TickLearner(4.0)], average_period=2, max_steps=6)
    launcher = get_launcher(backend)(prog).launch()
    try:
        launcher.join(timeout=JOIN_S)
    finally:
        launcher.stop()
    assert server.rounds == 3          # 6 steps / period 2
    # averaging pulled the two streams together: both replicas converged to
    # the shared mean trajectory
    v0 = prog.resolve("learner/replica_0").get_variables()[0]
    v1 = prog.resolve("learner/replica_1").get_variables()[0]
    assert v0 == v1
    # interface enforcement on the replica handle
    assert handles[0].get_variables() == [v0]
    with pytest.raises(AttributeError):
        handles[0].learner
    with pytest.raises(AttributeError):
        handles[0].run


@pytest.mark.parametrize("backend", BACKENDS)
def test_learner_replica_death_fails_fast(backend):
    """One replica dying stops its siblings (the survivor is released from
    the averaging barrier instead of waiting forever) and join surfaces
    the error."""
    prog, server, _ = _replica_program(
        [ExplodingLearner(blow_at=3), TickLearner(0.0)],
        average_period=2, max_steps=100)
    launcher = get_launcher(backend)(prog).launch()
    with pytest.raises(Exception) as exc_info:
        launcher.join(timeout=JOIN_S)
    assert "replica-boom" in str(exc_info.value)
    assert launcher.should_stop()
    assert server.stopped


@pytest.mark.parametrize("backend", BACKENDS)
def test_join_timeout_names_straggler_replica(backend):
    """A replica stuck inside a long learner step is named by JoinTimeout."""
    prog, _, _ = _replica_program([SleepyLearner()], average_period=100,
                                  max_steps=1)
    launcher = get_launcher(backend)(prog).launch()
    time.sleep(0.3)                     # let the replica enter its step
    launcher.stop()
    with pytest.raises(JoinTimeout) as exc_info:
        launcher.join(timeout=0.5)
    assert "learner/replica_0" in exc_info.value.node_names
    _cleanup(launcher)


def test_unserved_handle_refuses_to_pickle():
    prog = Program()
    handle = prog.add_node("source", Source, role="service")
    with pytest.raises(pickle.PicklingError):
        pickle.dumps(handle)


# ------------------------------------------------------------ program graph
def test_duplicate_node_rejected():
    prog = Program()
    prog.add_node("a", Source)
    with pytest.raises(ValueError):
        prog.add_node("a", Source)


def test_bad_role_rejected():
    prog = Program()
    with pytest.raises(ValueError):
        prog.add_node("a", Source, role="supervisor")
    with pytest.raises(ValueError):
        prog.add_node("b", Source, role="worker", is_worker=True)


def test_is_worker_compat_spelling():
    prog = Program()
    prog.add_node("w", Spinner, is_worker=True)
    assert prog.node("w").role == "worker"
    assert prog.node("w").is_worker


def test_handle_dereference_is_lazy_and_cached():
    prog = Program()
    calls = []

    def factory():
        calls.append(1)
        return Source(1)

    h = prog.add_node("s", factory)
    assert not calls
    assert h.get() == 1
    assert h.get() == 1
    assert len(calls) == 1


def test_handle_enforces_declared_interface():
    prog = Program()
    h = prog.add_node("s", Source, role="service", interface=("get",))
    assert h.get() == 41
    with pytest.raises(AttributeError):
        h.value


def test_launcher_registry():
    assert get_launcher("local").backend == "local"
    assert get_launcher("multiprocess").backend == "multiprocess"
    with pytest.raises(ValueError, match="unknown launcher"):
        get_launcher("fleet-of-zeppelins")

    class DummyLauncher(LauncherBase):
        backend = "dummy-test"

        def launch(self):
            return self

    register_launcher("dummy-test", DummyLauncher)
    try:
        assert get_launcher("dummy-test") is DummyLauncher
        assert issubclass(DummyLauncher, Launcher)
    finally:
        from repro.distributed import launchers as launchers_lib
        launchers_lib._LAUNCHERS.pop("dummy-test", None)


# ----------------------------------------------------------------- courier
def test_courier_call_args_kwargs():
    class Calc:
        def mul(self, a, b=2):
            return a * b

    server, handle = serve(Calc(), name="calc")
    try:
        assert handle.mul(3) == 6
        assert handle.mul(3, b=5) == 15
        assert handle.call("mul", 4, b=4) == 16
    finally:
        server.stop()


def test_courier_preserves_exception_type():
    class Flaky:
        def blow(self):
            raise KeyError("missing-thing")

    server, handle = serve(Flaky(), name="flaky")
    try:
        with pytest.raises(KeyError, match="missing-thing"):
            handle.blow()
        # the connection survives a remote exception
        with pytest.raises(KeyError):
            handle.blow()
    finally:
        server.stop()


def test_courier_unpicklable_exception_becomes_remote_error():
    class Cursed(RuntimeError):
        def __init__(self):
            super().__init__("cursed")
            self.lock = threading.Lock()    # unpicklable payload

    class Target:
        def blow(self):
            raise Cursed()

    server, handle = serve(Target(), name="cursed")
    try:
        with pytest.raises(RemoteError, match="Cursed"):
            handle.blow()
    finally:
        server.stop()


def test_courier_server_enforces_interface():
    server, _ = serve(Source(7), interface=("get",), name="src")
    try:
        # bypass the client-side allowlist: the server still refuses
        sneaky = RemoteHandle(server.address, name="src", interface=None,
                              authkey=server.authkey)
        assert sneaky.get() == 7
        with pytest.raises(AttributeError, match="interface"):
            sneaky.call("value")
    finally:
        server.stop()


def test_courier_rejects_unauthenticated_connections():
    """The unpickling server must not accept frames from arbitrary local
    processes: connections without the authkey are refused before any
    payload is read."""
    server, handle = serve(Source(7), interface=("get",), name="src")
    try:
        intruder = RemoteHandle(server.address, name="src",
                                interface=("get",), authkey=b"wrong-key")
        with pytest.raises(ConnectionError, match="authentication"):
            intruder.get()
        keyless = RemoteHandle(server.address, name="src",
                               interface=("get",))
        with pytest.raises(ConnectionError, match="authentication"):
            keyless.get()
        assert handle.get() == 7      # the real client still works
    finally:
        server.stop()


class _TwoArgError(Exception):
    """Pickles via dumps but fails to REconstruct on loads (multi-arg
    __init__ with single-arg args tuple)."""

    def __init__(self, limit, used):
        super().__init__(f"quota {used}/{limit}")
        self.limit, self.used = limit, used


def test_courier_unreconstructable_exception_becomes_remote_error():
    class Target:
        def blow(self):
            raise _TwoArgError(10, 11)

    server, handle = serve(Target(), name="quota")
    try:
        with pytest.raises(RemoteError, match="_TwoArgError"):
            handle.blow()
    finally:
        server.stop()


def test_courier_unpicklable_response_becomes_remote_error():
    """A result that fails to pickle must answer as an error frame, not
    silently kill the connection."""
    class Target:
        def get_lock(self):
            return threading.Lock()

        def get_value(self):
            return 7

    server, handle = serve(Target(), name="locky")
    try:
        with pytest.raises(RemoteError, match="could not be pickled"):
            handle.get_lock()
        assert handle.get_value() == 7    # the connection survives
    finally:
        server.stop()


def test_courier_rate_limiter_timeout_crosses_the_wire():
    """Shutdown-noise classification depends on remote errors keeping their
    type: a RateLimiterTimeout raised server-side must re-raise as itself."""
    from repro.replay.rate_limiter import RateLimiterTimeout

    class Table:
        def insert(self):
            raise RateLimiterTimeout("stopped")

    server, handle = serve(Table(), name="table")
    try:
        with pytest.raises(RateLimiterTimeout):
            handle.insert()
    finally:
        server.stop()


# ------------------------------------------------------- variable satellite
def test_variable_server_empty_names_returns_all():
    from repro.core import VariableServer
    server = VariableServer(policy=[1, 2], critic=[3])
    assert server.get_variables(()) == [[1, 2], [3]]
    assert server.get_variables() == [[1, 2], [3]]
    assert server.get_variables(("critic",)) == [[3]]


def test_variable_source_served_over_courier():
    from repro.core import VariableClient, VariableServer
    from repro.core.variable import serve_variable_source
    vs = VariableServer(policy=[1, 2, 3])
    server, handle = serve_variable_source(vs)
    try:
        client = VariableClient(handle)
        assert client.params == [1, 2, 3]
        vs.publish("policy", [4])
        client.update(wait=True)
        assert client.params == [4]
        # empty names over RPC: all published variables
        assert handle.get_variables(()) == [[4]]
        with pytest.raises(AttributeError):
            handle.publish("policy", [5])   # not in the served interface
    finally:
        server.stop()


class _CountingSource:
    def __init__(self):
        self.fetches = 0

    def get_variables(self, names=()):
        self.fetches += 1
        return [[self.fetches]]


def test_variable_client_no_initial_double_fetch():
    """params populated by the property accessor must not be re-fetched by
    the immediately following update(wait=False)."""
    from repro.core import VariableClient
    source = _CountingSource()
    client = VariableClient(source, update_period=1)
    assert client.params == [1]
    assert source.fetches == 1
    client.update(wait=False)          # just fetched: deduped
    assert source.fetches == 1
    client.update(wait=False)          # cadence resumes (period=1)
    assert source.fetches == 2


def test_variable_client_period_still_honoured():
    from repro.core import VariableClient
    source = _CountingSource()
    client = VariableClient(source, update_period=5)
    for _ in range(10):
        client.update()
    # fetch on first call (no params yet) + every 5th call
    assert source.fetches == 3
    client.update(wait=True)
    assert source.fetches == 4


# --------------------------------------------- multiprocess learning smoke
@pytest.mark.slow
def test_multiprocess_dqn_on_catch_learning_smoke():
    """Acceptance: the UNCHANGED DQNBuilder trains on Catch with actors in
    separate OS processes, pulling weights via the courier-served learner
    and feeding replay (sharded, to exercise shard service nodes) over
    courier RPC."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, num_replay_shards=2,
        launcher="multiprocess")
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000,
                                        timeout_s=240,
                                        with_evaluator=True)
    counts = result.counts
    assert counts.get("actor_steps", 0) >= 4000, counts
    assert result.learner_steps > 50
    assert result.extras["launcher"] == "multiprocess"
    assert result.extras["inserts"] > result.extras["min_size_to_sample"]
    assert result.extras["samples"] > 0
    # SPI accounting still holds across the RPC boundary (loose bound:
    # shards cross min-size thresholds independently)
    assert 1.0 < result.extras["spi_effective"] < 8.0
    # the remote evaluator reported through its service node
    assert len(result.extras["evaluator_returns"]) >= 1
    # sharded replay: both shard services saw inserts
    per_shard = result.extras["replay"]["per_shard"]
    assert len(per_shard) == 2 and all(s["inserts"] > 0 for s in per_shard)
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6
