"""Launchpad-lite: program graph construction, handle transparency, and the
actor/learner/replay triangle under the rate limiter."""
import threading
import time

import pytest

from repro.distributed.program import Handle, LocalLauncher, Program


class Source:
    def __init__(self, value=41):
        self.value = value

    def get(self):
        return self.value


class Consumer:
    def __init__(self, source):
        # the key Launchpad property: source may be a Handle or the object;
        # the code below cannot tell the difference.
        self.source = source
        self.result = None

    def run(self):
        self.result = self.source.get() + 1


def test_program_edges_look_like_method_calls():
    prog = Program()
    src = prog.add_node("source", Source, 41)
    prog.add_node("consumer", Consumer, src, is_worker=True)
    launcher = LocalLauncher(prog).launch()
    launcher.join(timeout=5)
    assert prog.resolve("consumer").result == 42


def test_duplicate_node_rejected():
    prog = Program()
    prog.add_node("a", Source)
    with pytest.raises(ValueError):
        prog.add_node("a", Source)


def test_handle_dereference_is_lazy_and_cached():
    prog = Program()
    calls = []

    def factory():
        calls.append(1)
        return Source(1)

    h = prog.add_node("s", factory)
    assert not calls
    assert h.get() == 1
    assert h.get() == 1
    assert len(calls) == 1


def test_worker_stop():
    class Loop:
        def __init__(self):
            self._stop = threading.Event()
            self.iterations = 0

        def run(self):
            while not self._stop.is_set():
                self.iterations += 1
                time.sleep(0.01)

        def stop(self):
            self._stop.set()

    prog = Program()
    prog.add_node("loop", Loop, is_worker=True)
    launcher = LocalLauncher(prog).launch()
    time.sleep(0.2)
    launcher.stop()
    launcher.join(timeout=5)
    assert prog.resolve("loop").iterations > 0
