"""HLO text analyzer: trip-count weighting, collective bytes, dot flops."""
import textwrap

from repro.launch import hlo_analysis as H

MODULE = textwrap.dedent("""
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16] get-tuple-element(%p), index=1
      %w = f32[16,16] constant(0)
      %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16] all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %lim = s32[] constant(12)
      ROOT %lt = pred[] compare(%i, %lim), direction=LT
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %x)
      %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
    }
""")


def test_shape_bytes():
    assert H.shape_bytes("f32[8,16]") == 8 * 16 * 4
    assert H.shape_bytes("bf16[2,3]{1,0}") == 12
    assert H.shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert H.shape_bytes("pred[]") == 1


def test_while_trip_count_weights_flops_and_collectives():
    an = H.analyze(MODULE)
    # dot: 2*8*16*16 flops, executed 12 times
    assert an.flops == 2 * 8 * 16 * 16 * 12
    # all-reduce operand 8*16*4 bytes, 12 times
    assert an.collective_bytes == 8 * 16 * 4 * 12
    assert an.collectives["all-reduce"]["count"] == 12


def test_collective_kind_split():
    an = H.analyze(MODULE)
    assert set(an.collectives) == {"all-reduce"}


def test_parse_module_finds_entry():
    comps = H.parse_module(MODULE)
    assert comps["__entry__"].name == "main"
    names = {c.name for c in comps.values()}
    assert {"body", "cond", "add"} <= names
