import numpy as np
import pytest

from repro.core import StepType, make_environment_spec
from repro.envs import Bandit, CartpoleSwingup, Catch, DeepSea, MemoryChain, PendulumSwingup, TokenChain

ENVS = [
    lambda: Catch(seed=0),
    lambda: DeepSea(size=6, seed=0),
    lambda: DeepSea(size=6, stochastic=True, seed=0),
    lambda: CartpoleSwingup(seed=0, episode_len=50),
    lambda: PendulumSwingup(seed=0, episode_len=50),
    lambda: MemoryChain(memory_length=5, seed=0),
    lambda: Bandit(seed=0),
    lambda: TokenChain(vocab_size=16, episode_len=20, seed=0),
]


@pytest.mark.parametrize("factory", ENVS)
def test_env_contract(factory):
    env = factory()
    spec = make_environment_spec(env)
    ts = env.reset()
    assert ts.step_type == StepType.FIRST
    assert ts.reward is None
    spec.observations.validate(ts.observation)
    steps = 0
    while not ts.last() and steps < 2000:
        if hasattr(spec.actions, "num_values"):
            a = np.random.randint(spec.actions.num_values)
        else:
            a = np.zeros(spec.actions.shape, np.float32)
        ts = env.step(a)
        assert isinstance(ts.reward, float) or np.isscalar(ts.reward)
        spec.observations.validate(ts.observation)
        steps += 1
    assert ts.last(), "episode must terminate"
    assert ts.discount == 0.0 or ts.discount == 1.0


def test_deep_sea_optimal_policy_finds_treasure():
    env = DeepSea(size=8, seed=1)
    ts = env.reset()
    total = 0.0
    while not ts.last():
        ts = env.step(env.optimal_action())
        total += ts.reward
    assert total > 0.9


def test_catch_optimal_paddle_tracking_wins():
    env = Catch(seed=3)
    for _ in range(5):
        ts = env.reset()
        while not ts.last():
            board = ts.observation
            ball_col = int(np.argmax(board[:-1].max(axis=0)))
            paddle_col = int(np.argmax(board[-1]))
            a = 1 + np.sign(ball_col - paddle_col)
            ts = env.step(int(a))
        assert ts.reward == 1.0
