"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import layers
from repro.replay.selectors import SumTree


# ------------------------------------------------------------- sum tree
@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=64),
       st.floats(0.0, 0.999))
def test_sumtree_find_respects_masses(priorities, u):
    tree = SumTree(128)
    for i, p in enumerate(priorities):
        tree.set(i, p)
    total = tree.total()
    assert total == pytest.approx(sum(priorities), rel=1e-6)
    idx = tree.find(u * total)
    assert 0 <= idx < 128
    assert tree.get(idx) > 0  # never lands on an empty slot


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.01, 10.0), min_size=4, max_size=32),
       st.integers(0, 31))
def test_sumtree_update_consistency(priorities, victim):
    tree = SumTree(64)
    for i, p in enumerate(priorities):
        tree.set(i, p)
    victim = victim % len(priorities)
    tree.set(victim, 0.0)
    assert tree.total() == pytest.approx(sum(priorities) - priorities[victim],
                                         rel=1e-6, abs=1e-9)


# ------------------------------------------------------------- chunked CE
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 12, 16]), st.integers(0, 2 ** 31 - 1))
def test_chunked_ce_matches_plain_ce(b, s, seed):
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    d, v = 16, 32
    x = jax.random.normal(k1, (b, s, d))
    table = jax.random.normal(k2, (v, d))
    labels = jax.random.randint(k3, (b, s), 0, v)
    plain = layers.cross_entropy(layers.unembed(table, x), labels)
    chunked = layers.chunked_cross_entropy(x, table, labels, chunk=4)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)


# ------------------------------------------------------------- rope
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_rope_preserves_norm_and_relative_angles(seed):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (1, 6, 2, 8))
    pos = jnp.arange(6)
    y = layers.apply_rope(x, pos[None, :], theta=10_000.0)
    # rotation: per-position vector norms unchanged
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(x, axis=-1)),
                               np.asarray(jnp.linalg.norm(y, axis=-1)),
                               rtol=1e-5)
    # relative property: <q_i, k_j> depends only on i - j
    q = jax.random.normal(jax.random.fold_in(key, 1), (8,))
    k = jax.random.normal(jax.random.fold_in(key, 2), (8,))
    def rot(vec, p):
        v = vec.reshape(1, 1, 1, 8)
        return layers.apply_rope(v, jnp.array([[p]]), 10_000.0).reshape(8)
    d1 = float(jnp.dot(rot(q, 3), rot(k, 1)))
    d2 = float(jnp.dot(rot(q, 7), rot(k, 5)))
    assert d1 == pytest.approx(d2, rel=1e-4, abs=1e-4)


# ------------------------------------------------------------- moe mass
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_moe_combine_weights_bounded(seed):
    """Every token's combine weights sum to <= 1 (drops) and >= 0."""
    import dataclasses
    from repro.configs import ARCHS, reduced
    from repro.models import moe as moe_lib, transformer
    cfg = reduced(ARCHS["qwen2-moe-a2.7b"])
    key = jax.random.key(seed)
    params = moe_lib.moe_init(key, cfg, jnp.float32)
    x = 0.5 * jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = moe_lib.moe_ffn(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["moe_aux"]) >= 0.0


# ------------------------------------------------------------- sliding window
def test_sliding_window_masks_far_tokens():
    from repro.kernels import ref
    q = jnp.ones((1, 1, 8, 4))
    k = jnp.ones((1, 1, 8, 4))
    v = jnp.broadcast_to(jnp.arange(8.0).reshape(1, 1, 8, 1), (1, 1, 8, 4))
    out_full = ref.flash_attention_ref(q, k, v, causal=True)
    out_win = ref.flash_attention_ref(q, k, v, causal=True, window=2)
    # with window 2, position 7 attends to {6, 7}: mean value 6.5
    assert float(out_win[0, 0, 7, 0]) == pytest.approx(6.5, abs=1e-4)
    # full attention averages 0..7: 3.5
    assert float(out_full[0, 0, 7, 0]) == pytest.approx(3.5, abs=1e-4)
