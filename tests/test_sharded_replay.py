"""Sharded replay service + prefetching pipeline (ISSUE 2).

Covers: shard-key encoding and routing, interleaved sampling, the
multi-threaded stress invariants (size, key-routing, per-shard SPI), the
prefetching dataset, fail-fast launching, rate-limiter stop symmetry, and
the sharded execution paths — every registered builder through a 4-shard
distributed program, plus sharded-vs-single learning through one
``ExperimentConfig``.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

import repro.agents  # noqa: F401  (imports register all builders)
from repro.builders import BuilderOptions, registered_builders
from repro.envs import Catch, DeepSea, PendulumSwingup
from repro.replay import (Fifo, MinSize, PrefetchingDataset, Prioritized,
                          RateLimiterTimeout, SampleToInsertRatio,
                          ShardedReplay, Table, Uniform, as_iterator,
                          make_replay_shards)

from tests.test_builders_api import FACTORIES


def _uniform_factory(capacity=1000, min_size=1):
    return lambda: Table("t", capacity, Uniform(0), MinSize(min_size))


# --------------------------------------------------------------- unit tests
def test_round_robin_routing_balances_shards():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    for i in range(20):
        sr.insert(i)
    assert [s.size() for s in sr.shards] == [5, 5, 5, 5]
    assert sr.size() == 20


def test_hash_routing_balances_shards():
    sr = ShardedReplay.from_factory(_uniform_factory(10_000), 4,
                                    routing="hash")
    for i in range(1000):
        sr.insert(i)
    sizes = [s.size() for s in sr.shards]
    assert min(sizes) > 150, sizes


def test_global_keys_encode_owning_shard():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    keys = [sr.insert({"v": i}) for i in range(16)]
    assert len(set(keys)) == 16, "global keys must be unique"
    for i, key in enumerate(keys):
        idx, local = sr.shard_of(key), key // sr.num_shards
        assert idx == i % 4                   # round-robin placement
        assert sr.shards[idx]._items[local].data == {"v": i}


def test_sampled_items_carry_global_keys_and_scaled_probs():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    for i in range(16):
        sr.insert(i)
    for item, prob in sr.sample(8):
        idx, local = sr.shard_of(item.key), item.key // 4
        assert sr.shards[idx]._items[local].data == item.data
        # per-shard uniform prob (1/4) scaled by the shard mixture (1/4)
        assert prob == pytest.approx(1 / 16)


def test_update_priorities_routes_to_owning_shard():
    sr = ShardedReplay.from_factory(
        lambda: Table("t", 100, Prioritized(priority_exponent=1.0),
                      MinSize(1)), 4)
    keys = [sr.insert(i, priority=1.0) for i in range(8)]
    sr.update_priorities(keys, [float(10 + i) for i in range(8)])
    for i, key in enumerate(keys):
        idx, local = sr.shard_of(key), key // 4
        assert sr.shards[idx]._items[local].priority == float(10 + i)


def test_interleaved_sampling_touches_every_shard():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    for i in range(8):
        sr.insert(i)
    shards_hit = {sr.shard_of(item.key) for item, _ in sr.sample(8)}
    assert shards_hit == {0, 1, 2, 3}


def test_aggregate_stats_and_stop():
    sr = ShardedReplay.from_factory(_uniform_factory(), 2)
    for i in range(10):
        sr.insert(i)
    sr.sample(4)
    stats = sr.stats()
    assert stats["num_shards"] == 2
    assert stats["inserts"] == sr.rate_limiter.inserts == 10
    assert stats["samples"] == sr.rate_limiter.samples == 4
    assert sum(p["inserts"] for p in stats["per_shard"]) == 10
    assert not sr.stopped
    sr.stop()
    assert sr.stopped and all(s.stopped for s in sr.shards)


def test_make_replay_shards_passthrough_single():
    table = make_replay_shards(_uniform_factory(), 1)
    assert isinstance(table, Table)
    assert isinstance(make_replay_shards(_uniform_factory(), 4),
                      ShardedReplay)


def test_sharded_fifo_preserves_global_order_single_threaded():
    sr = ShardedReplay.from_factory(
        lambda: Table("q", 100, Fifo(), MinSize(1)), 4)
    for i in range(12):
        sr.insert(i)
    got = [item.data for item, _ in sr.sample(12)]
    assert got == list(range(12))


def test_sharded_queue_survives_uneven_drain():
    """A batch size that doesn't divide the shard count skews consumption;
    an empty queue shard must block (not IndexError) until inserts arrive,
    with the admitted-but-unserved sample rolled back."""
    sr = ShardedReplay.from_factory(
        lambda: Table("q", 100, Fifo(), MinSize(2)), 3)
    for i in range(9):
        sr.insert(i)
    sr.sample(7)
    sr.sample(2)   # table now empty on some shards
    with pytest.raises(RateLimiterTimeout):
        sr.sample(5, timeout=0.2)
    # the rolled-back sample is not counted against the SPI budget
    assert sr.rate_limiter.samples == 9
    sr.insert(100)  # an insert unblocks the starved shard again
    before = sr.rate_limiter.samples
    got = sr.sample(1, timeout=1.0)
    assert len(got) == 1
    assert sr.rate_limiter.samples == before + 1


def test_shard_selectors_get_distinct_rng_streams():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    for i in range(400):
        sr.insert(i)
    draws = [[s.selector.sample()[0] for _ in range(20)] for s in sr.shards]
    assert len({tuple(d) for d in draws}) == 4, (
        "shards replayed identical RNG streams")


def test_offline_builder_never_sharded():
    """Offline replay is a preloaded dataset: sharding would duplicate it
    per shard, so the execution layers pin offline builders to one table."""
    from repro.agents.builders import _effective_shards
    from tests.test_builders_api import _make_bc

    builder, _ = _make_bc()
    assert builder.options.offline
    assert _effective_shards(builder.options, 4) == 1
    assert _effective_shards(builder.options, None) == 1


def test_builder_options_sharding_fields():
    opts = BuilderOptions(num_replay_shards=4, prefetch_size=2)
    assert opts.num_replay_shards == 4 and opts.prefetch_size == 2
    with pytest.raises(ValueError):
        BuilderOptions(num_replay_shards=0)
    with pytest.raises(ValueError):
        BuilderOptions(prefetch_size=-1)


# ------------------------------------------------------- rate limiter stop
def test_await_can_insert_raises_after_stop():
    """Satellite: a blocked insert must raise on stop() instead of falling
    through and counting a phantom insert (symmetric with the sample path)."""
    limiter = SampleToInsertRatio(samples_per_insert=1.0,
                                  min_size_to_sample=1, error_buffer=2.0)
    # drive inserts ahead until blocked
    n = 0
    try:
        for _ in range(100):
            limiter.await_can_insert(timeout=0.02)
            n += 1
    except RateLimiterTimeout:
        pass
    assert n < 100, "insert never blocked"
    before = limiter.inserts
    threading.Timer(0.1, limiter.stop).start()
    with pytest.raises(RateLimiterTimeout, match="stopped"):
        limiter.await_can_insert(timeout=5.0)
    assert limiter.inserts == before, "stop() counted a phantom insert"


# ------------------------------------------------------------- stress tests
@pytest.mark.parametrize("make_table", [
    pytest.param(lambda: Table("t", 500, Uniform(0), MinSize(4)),
                 id="single_table"),
    pytest.param(lambda: ShardedReplay.from_factory(
        lambda: Table("t", 500, Uniform(0), MinSize(4)), 4),
        id="sharded_4"),
])
def test_concurrent_stress_preserves_invariants(make_table):
    """Concurrent insert/sample/update_priorities: size stays within
    capacity, sampled keys route to live items, nothing deadlocks."""
    table = make_table()
    capacity = 500 * getattr(table, "num_shards", 1)
    stop = time.time() + 1.0
    errors = []
    sampled_keys = []

    def actor(tid):
        i = 0
        while time.time() < stop:
            try:
                table.insert({"v": np.array([tid, i])}, priority=1.0,
                             timeout=0.2)
            except RateLimiterTimeout:
                pass
            except Exception as e:   # noqa: BLE001 — collect for the assert
                errors.append(e)
                return
            i += 1

    def learner():
        while time.time() < stop:
            try:
                out = table.sample(4, timeout=0.2)
                sampled_keys.extend(item.key for item, _ in out)
                table.update_priorities(
                    [item.key for item, _ in out],
                    [float(np.random.rand()) for _ in out])
            except RateLimiterTimeout:
                pass
            except Exception as e:   # noqa: BLE001
                errors.append(e)
                return

    threads = ([threading.Thread(target=actor, args=(t,)) for t in range(3)]
               + [threading.Thread(target=learner) for _ in range(2)])
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert not any(t.is_alive() for t in threads), "stress test deadlocked"
    assert not errors, errors
    assert 0 < table.size() <= capacity
    shards = getattr(table, "shards", [table])
    for shard in shards:
        # per-shard bookkeeping stayed consistent under concurrency
        assert set(shard._items) == set(shard._order)
        assert shard.size() <= shard.capacity
    if isinstance(table, ShardedReplay):
        assert table.size() == sum(s.size() for s in shards)
        assert {k % table.num_shards for k in sampled_keys} == {0, 1, 2, 3}


def test_concurrent_sharded_spi_invariant_per_shard():
    """§2.5 under sharding: each shard's own limiter holds its SPI bound."""
    spi, min_size, tol = 2.0, 8, 10.0
    sr = ShardedReplay.from_factory(
        lambda: Table("t", 10_000, Uniform(0),
                      SampleToInsertRatio(spi, min_size, tol)), 4)
    stop = time.time() + 1.0

    def actor():
        while time.time() < stop:
            try:
                sr.insert(np.zeros(2), timeout=0.2)
            except RateLimiterTimeout:
                pass

    def learner():
        while time.time() < stop:
            try:
                sr.sample(4, timeout=0.2)
            except RateLimiterTimeout:
                pass

    threads = ([threading.Thread(target=actor) for _ in range(2)]
               + [threading.Thread(target=learner) for _ in range(2)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sr.rate_limiter.inserts > 4 * min_size
    for shard in sr.shards:
        rl = shard.rate_limiter
        deficit = rl.samples - spi * (rl.inserts - min_size)
        assert abs(deficit) <= tol + spi * 8, (shard.name, deficit)


# ----------------------------------------------------------------- prefetch
def test_prefetching_dataset_direct_mode():
    table = _uniform_factory()()
    for i in range(20):
        table.insert({"obs": np.full((3,), i, np.float32)})
    ds = PrefetchingDataset(table, batch_size=4, prefetch_size=4,
                            num_threads=2)
    for _ in range(5):
        sample = next(ds)
        assert sample.data["obs"].shape == (4, 3)
        assert sample.info.keys.shape == (4,)
    ds.stop()


def test_prefetching_dataset_over_iterator():
    table = _uniform_factory()()
    for i in range(20):
        table.insert({"obs": np.full((3,), i, np.float32)})
    ds = PrefetchingDataset.over_iterator(as_iterator(table, 4))
    assert next(ds).data["obs"].shape == (4, 3)
    ds.stop()


def test_prefetching_dataset_stops_with_table():
    table = Table("t", 100, Uniform(0), MinSize(50))  # sampling blocked
    table.insert(0)
    ds = PrefetchingDataset(table, batch_size=1, prefetch_size=2)
    table.stop()
    with pytest.raises(RateLimiterTimeout, match="stopped"):
        for _ in range(100):   # bounded: must raise once workers notice
            next(ds)
    ds.stop()


def test_prefetching_dataset_over_sharded_replay():
    sr = ShardedReplay.from_factory(_uniform_factory(), 4)
    for i in range(32):
        sr.insert({"x": np.array([i], np.float32)})
    ds = PrefetchingDataset(sr, batch_size=8, prefetch_size=2,
                            num_threads=2)
    sample = next(ds)
    assert sample.data["x"].shape == (8, 1)
    assert len({int(k) % 4 for k in sample.info.keys}) == 4
    ds.stop()


# ------------------------------------------------------- fail-fast launcher
def test_launcher_fails_fast_stops_siblings():
    """Satellite: the first worker exception must stop sibling nodes instead
    of letting them spin until an external timeout."""
    from repro.distributed.program import LocalLauncher, Program

    class Exploder:
        def run(self):
            time.sleep(0.05)
            raise RuntimeError("boom")

    class Spinner:
        def __init__(self):
            self._stop = threading.Event()
            self.iterations = 0

        def run(self):
            while not self._stop.is_set():
                self.iterations += 1
                time.sleep(0.01)

        def stop(self):
            self._stop.set()

    prog = Program()
    prog.add_node("exploder", Exploder, is_worker=True)
    prog.add_node("spinner", Spinner, is_worker=True)
    launcher = LocalLauncher(prog).launch()
    t0 = time.time()
    with pytest.raises(RuntimeError, match="boom"):
        launcher.join(timeout=30)
    assert time.time() - t0 < 10, "siblings were not stopped promptly"
    assert launcher.should_stop()
    assert prog.resolve("spinner")._stop.is_set()


# ------------------------------------------------- sharded execution paths
def _env_factory_for(env):
    if isinstance(env, DeepSea):
        return lambda s: DeepSea(size=4, seed=s)
    if isinstance(env, PendulumSwingup):
        return lambda s: PendulumSwingup(seed=s, episode_len=30)
    return lambda s: Catch(seed=s)


@pytest.mark.parametrize("cls", registered_builders(),
                         ids=lambda c: c.__name__)
def test_distributed_conformance_with_four_shards(cls):
    """Acceptance: every registered builder runs unchanged on a 4-shard
    replay service with a prefetching learner pipeline."""
    from repro.agents.builders import make_distributed_agent

    factory = FACTORIES.get(cls.__name__)
    assert factory is not None, f"no conformance factory for {cls.__name__}"
    builder, env = factory()
    dist = make_distributed_agent(builder, _env_factory_for(env),
                                  num_actors=2, seed=0,
                                  num_replay_shards=4, prefetch_size=2)
    try:
        if builder.options.offline:
            # offline replay is a preloaded fixed dataset — sharding would
            # only duplicate it, so the execution layer keeps one table
            assert isinstance(dist.table, Table)
        else:
            assert isinstance(dist.table, ShardedReplay)
            assert dist.table.num_shards == 4
            node_names = {n.name for n in dist.program.nodes}
            assert {f"replay/shard_{i}" for i in range(4)} <= node_names
        deadline = time.time() + 30
        while time.time() < deadline:
            if (dist.table.size() >= 4
                    and int(dist.learner.state.steps) > 0):
                break
            time.sleep(0.1)
        if not builder.options.offline:
            stats = dist.table.stats()
            assert all(p["inserts"] > 0 for p in stats["per_shard"]), (
                f"insert routing missed a shard: {stats}")
        assert int(dist.learner.state.steps) > 0, (
            "learner never stepped through the sharded service")
    finally:
        dist.stop()


def test_sharded_vs_single_learning_equivalence_one_config():
    """One ExperimentConfig, two replay topologies: 1 shard vs 4 shards both
    drive the same DQN builder to a learning run with finite evals."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    config = make_dqn_catch_config(seed=0, min_replay_size=16,
                                   samples_per_insert=0.0,
                                   num_episodes=30, eval_episodes=5)

    single = run_experiment(config)
    sharded = run_experiment(
        dataclasses.replace(config, num_replay_shards=4))
    for result in (single, sharded):
        assert result.learner_steps > 0
        assert np.isfinite(result.final_eval_return)
    # same builder class, same config → comparable learner schedules
    assert type(single.builder) is type(sharded.builder)
    ratio = (sharded.learner_steps + 1) / (single.learner_steps + 1)
    assert 0.2 < ratio < 5.0, (single.learner_steps, sharded.learner_steps)


def test_run_distributed_experiment_sharded_extras():
    """run_distributed_experiment(num_replay_shards=4) reports aggregated
    and per-shard replay stats, with the SPI invariant held per shard."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    spi, min_size = 4.0, 8
    config = make_dqn_catch_config(
        seed=0, min_replay_size=min_size, samples_per_insert=spi,
        eval_episodes=2, num_replay_shards=4, prefetch_size=4)
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=400, timeout_s=60)
    assert result.learner_steps > 0
    replay = result.extras["replay"]
    assert replay["num_shards"] == 4
    assert replay["inserts"] == sum(p["inserts"]
                                    for p in replay["per_shard"])
    # §2.5 invariant per shard (error buffer from DQNBuilder.make_replay)
    error_buffer = max(spi * 2 * 16, 100.0)
    for p in replay["per_shard"]:
        if p["inserts"] <= min_size:
            continue
        deficit = p["samples"] - spi * (p["inserts"] - min_size)
        # slack: prefetch keeps up to prefetch_size batches in flight
        assert abs(deficit) <= error_buffer + spi * 16 * 4, (p, deficit)
