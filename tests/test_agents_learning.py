"""Learning smoke tests per agent family: a handful of episodes must reduce
loss and/or improve return on a toy task (kept short for CPU CI)."""
import numpy as np
import pytest

from repro.agents.builders import make_agent
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import Catch, DeepSea, MemoryChain, PendulumSwingup


def _returns(env, agent, n):
    loop = EnvironmentLoop(env, agent)
    return [loop.run_episode()["episode_return"] for _ in range(n)]


def test_impala_learns_catch():
    from repro.agents.impala import IMPALABuilder, IMPALAConfig
    env = Catch(seed=2)
    spec = make_environment_spec(env)
    cfg = IMPALAConfig(sequence_length=5, batch_size=4, learning_rate=3e-3,
                       entropy_cost=0.02)
    agent = make_agent(IMPALABuilder(spec, cfg, seed=1))
    rets = _returns(env, agent, 600)
    assert np.mean(rets[-50:]) > np.mean(rets[:50]) + 0.3


def test_r2d2_solves_memory_task():
    from repro.agents.r2d2 import R2D2Builder, R2D2Config
    env = MemoryChain(memory_length=5, seed=3)
    spec = make_environment_spec(env)
    cfg = R2D2Config(sequence_length=6, period=3, burn_in=0, batch_size=16,
                     min_replay_size=60, samples_per_insert=0,
                     target_update_period=40, epsilon=0.15)
    agent = make_agent(R2D2Builder(spec, cfg, seed=2))
    rets = _returns(env, agent, 350)
    # a memoryless policy gets 0 on average; R2D2 must beat that
    assert np.mean(rets[-60:]) > 0.3


def test_d4pg_improves_pendulum():
    from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
    env = PendulumSwingup(seed=1, episode_len=120)
    spec = make_environment_spec(env)
    cfg = ContinuousConfig(algo="d4pg", hidden=64, batch_size=64,
                           min_replay_size=300, samples_per_insert=0,
                           n_step=3, vmin=0.0, vmax=120.0, num_atoms=31,
                           sigma=0.3, target_update_period=50)
    agent = make_agent(ContinuousBuilder(spec, cfg, seed=3))
    rets = _returns(env, agent, 60)
    assert np.mean(rets[-10:]) > np.mean(rets[:10])


def test_mpo_runs_and_updates():
    from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
    env = PendulumSwingup(seed=2, episode_len=60)
    spec = make_environment_spec(env)
    cfg = ContinuousConfig(algo="mpo", hidden=32, batch_size=32,
                           min_replay_size=120, samples_per_insert=0,
                           mpo_samples=8, target_update_period=25)
    agent = make_agent(ContinuousBuilder(spec, cfg, seed=4))
    rets = _returns(env, agent, 12)
    assert int(agent.learner.state.steps) > 0
    assert np.isfinite(rets).all()


def test_dmpo_runs_and_updates():
    from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
    env = PendulumSwingup(seed=5, episode_len=60)
    spec = make_environment_spec(env)
    cfg = ContinuousConfig(algo="dmpo", hidden=32, batch_size=32,
                           min_replay_size=120, samples_per_insert=0,
                           mpo_samples=8, vmin=0.0, vmax=60.0, num_atoms=21)
    agent = make_agent(ContinuousBuilder(spec, cfg, seed=5))
    rets = _returns(env, agent, 12)
    assert int(agent.learner.state.steps) > 0
    assert np.isfinite(rets).all()


def test_dqfd_uses_demos_on_deep_sea():
    from repro.agents.dqfd import DQfDBuilder, DQfDConfig, generate_deep_sea_demos
    env = DeepSea(size=6, seed=1)
    spec = make_environment_spec(env)
    demos = generate_deep_sea_demos(DeepSea(size=6, seed=1), num_demos=20)
    assert len(demos) > 0
    cfg = DQfDConfig(min_replay_size=60, samples_per_insert=0, batch_size=32,
                     n_step=1, demo_ratio=0.5, epsilon=0.1)
    agent = make_agent(DQfDBuilder(spec, demos, cfg, seed=0))
    rets = _returns(env, agent, 250)
    # random exploration finds the treasure w.p. 2^-6; demos make it routine
    assert np.mean(np.asarray(rets[-50:]) > 0.5) > 0.2


def test_mcts_actor_plans_catch():
    import jax
    from repro.agents.mcts import MCTSActor, MCTSConfig, make_network
    from repro.core import VariableClient
    from repro.core.variable import VariableServer

    env = Catch(seed=4)
    spec = make_environment_spec(env)
    cfg = MCTSConfig(num_simulations=48, search_depth=12, temperature=0.25)
    init, _, _, _ = make_network(spec, cfg)
    server = VariableServer(policy=init(jax.random.key(0)))
    actor = MCTSActor(spec, cfg, VariableClient(server), model_env=env, seed=0)
    rets = []
    for _ in range(10):
        ts = env.reset()
        total = 0.0
        while not ts.last():
            a = actor.select_action(ts.observation)
            ts = env.step(a)
            total += ts.reward
        rets.append(total)
    # with a perfect simulator and pure search, MCTS should track the ball
    assert np.mean(rets) > 0.4
