"""Suite-wide guards: a per-test watchdog (dumps all thread stacks and
aborts if any single test exceeds WATCHDOG_S — learning tests are slow on
one CPU core, but nothing should exceed this) and small hypothesis budgets.

NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device; the
dry-run subprocess test sets its own 512-device env.
"""
import faulthandler

import pytest

WATCHDOG_S = 900


@pytest.fixture(autouse=True)
def _watchdog():
    faulthandler.dump_traceback_later(WATCHDOG_S, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()
