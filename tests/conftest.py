"""Suite-wide guards and shared fixtures.

Guards: a per-test watchdog (dumps all thread stacks and aborts if any
single test exceeds WATCHDOG_S — learning tests are slow on one CPU core,
but nothing should exceed this) and small hypothesis budgets.

Fixtures: the DQN-on-Catch smoke ``ExperimentConfig`` factory shared by
``test_builders_api`` / ``test_sharded_replay`` / ``test_vectorized`` /
``test_distributed`` / ``test_multi_learner`` — previously copy-pasted per
file.  The factory classes are module-level and picklable BY REFERENCE to
this module, so the multiprocess backend can ship them into spawn children
(pytest puts this directory on ``sys.path``; spawn children inherit it).

NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device; the
dry-run subprocess test sets its own 512-device env.
"""
import faulthandler

import pytest

WATCHDOG_S = 900


@pytest.fixture(autouse=True)
def _watchdog():
    faulthandler.dump_traceback_later(WATCHDOG_S, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


# ------------------------------------------- shared DQN-on-Catch fixtures
class DQNCatchBuilderFactory:
    """Picklable ``spec -> DQNBuilder`` factory over Catch-sized smoke
    presets; keyword knobs override ``DQNConfig`` fields."""

    DEFAULTS = dict(min_replay_size=50, samples_per_insert=4.0,
                    batch_size=16, n_step=1, epsilon=0.2)

    def __init__(self, seed: int = 0, **cfg_overrides):
        self.seed = seed
        self.cfg_kwargs = dict(self.DEFAULTS)
        self.cfg_kwargs.update(cfg_overrides)

    def __call__(self, spec):
        from repro.agents.dqn import DQNBuilder, DQNConfig
        return DQNBuilder(spec, DQNConfig(**self.cfg_kwargs), seed=self.seed)


class CatchEnvFactory:
    """Picklable ``seed -> Catch`` factory."""

    def __call__(self, seed):
        from repro.envs import Catch
        return Catch(seed=seed)


catch_env_factory = CatchEnvFactory()


class TransformerCatchBuilderFactory:
    """Picklable ``spec -> TransformerPolicyBuilder`` factory over
    Catch-sized smoke presets; keyword knobs override
    ``TransformerPolicyConfig`` fields.  ``samples_per_insert=0.0`` keeps
    the synchronous agent loop from blocking mid-learner-step (sequence
    adders insert ~once per Catch episode)."""

    DEFAULTS = dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                    head_dim=16, d_ff=64, window=4, sequence_length=10,
                    period=10, batch_size=8, min_replay_size=10,
                    samples_per_insert=0.0, backend="jnp")

    def __init__(self, seed: int = 0, **cfg_overrides):
        self.seed = seed
        self.cfg_kwargs = dict(self.DEFAULTS)
        self.cfg_kwargs.update(cfg_overrides)

    def __call__(self, spec):
        from repro.policies import (TransformerPolicyBuilder,
                                    TransformerPolicyConfig)
        return TransformerPolicyBuilder(
            spec, TransformerPolicyConfig(**self.cfg_kwargs), seed=self.seed)


def make_transformer_catch_config(*, seed: int = 0, builder_seed: int = None,
                                  **knobs):
    """One transformer-policy-on-Catch smoke ``ExperimentConfig``:
    ``TransformerPolicyConfig`` field names go to the builder factory,
    everything else to the config."""
    import dataclasses as _dc

    from repro.experiments import ExperimentConfig
    from repro.policies import TransformerPolicyConfig

    cfg_fields = {f.name for f in _dc.fields(TransformerPolicyConfig)}
    builder_knobs = {k: v for k, v in knobs.items() if k in cfg_fields}
    config_knobs = {k: v for k, v in knobs.items() if k not in cfg_fields}
    return ExperimentConfig(
        builder_factory=TransformerCatchBuilderFactory(
            seed=seed if builder_seed is None else builder_seed,
            **builder_knobs),
        environment_factory=catch_env_factory,
        seed=seed, **config_knobs)


def make_dqn_catch_config(*, seed: int = 0, builder_seed: int = None,
                          **knobs):
    """One DQN-on-Catch smoke ``ExperimentConfig``: ``DQNConfig`` field
    names go to the builder factory, everything else to the config."""
    import dataclasses as _dc

    from repro.agents.dqn import DQNConfig
    from repro.experiments import ExperimentConfig

    cfg_fields = {f.name for f in _dc.fields(DQNConfig)}
    builder_knobs = {k: v for k, v in knobs.items() if k in cfg_fields}
    config_knobs = {k: v for k, v in knobs.items() if k not in cfg_fields}
    return ExperimentConfig(
        builder_factory=DQNCatchBuilderFactory(
            seed=seed if builder_seed is None else builder_seed,
            **builder_knobs),
        environment_factory=catch_env_factory,
        seed=seed, **config_knobs)
